"""Probe profiling + training (paper §3.1, Figures 2/3).

Pipeline (all build-time, invoked from ``aot.py``):

1. *Profile*: generate the training workload, greedy-decode every request
   with the pure-jnp oracle, and harvest per-layer hidden states ("taps")
   with their remaining-length labels — the paper's "7 million training
   pairs", scaled to this model (~70k pairs x 9 tap points).
2. *Train*: one 2-layer MLP probe per tap point (vmapped joint training,
   hand-rolled Adam — optax is not in the image), plus the prompt-only
   probe that plays the role of the paper's BERT/S^3 baseline.
3. *Evaluate*: per-layer MAE with and without Bayesian refinement
   (Fig 2/3) on held-out requests; emit CSV + probe_weights.json.
"""

import csv
import functools
import json
import math
import os
from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .config import BINS, MODEL, PROBE, WORKLOAD
from .smoothing import smooth_sequence
from .workload import gen_requests


# ---------------------------------------------------------------------------
# Profiling
# ---------------------------------------------------------------------------

@dataclass
class ProfileData:
    """Harvested probe dataset.

    decode_x: [n, T, D] tap embeddings (T = n_taps tap points)
    decode_y: [n]       remaining-length bin labels
    decode_rem: [n]     raw remaining lengths (for MAE)
    decode_req: [n]     request index (groups a request's iterations)
    decode_t: [n]       iteration index within the request
    prompt_x: [m, D]    mean layer-0 prompt embeddings
    prompt_y: [m]       total-output-length bin labels
    prompt_n: [m]       raw total output lengths
    """

    decode_x: np.ndarray
    decode_y: np.ndarray
    decode_rem: np.ndarray
    decode_req: np.ndarray
    decode_t: np.ndarray
    prompt_x: np.ndarray
    prompt_y: np.ndarray
    prompt_n: np.ndarray


def profile_requests(params, requests, batch_size: int = 32,
                     max_steps: int = None) -> ProfileData:
    """Run every request to its true output length and harvest taps.

    Equivalent to what the serving engine sees: decode inputs are the
    dataset-replay response tokens (teacher forcing — see workload.py), so
    the full sequence `prompt ++ response` is known upfront and one causal
    full-forward reproduces every incremental decode step's hidden states
    exactly (asserted by python/tests/test_model.py). The tap at decode
    iteration j is the hidden state of the step-j input token, labelled
    remaining = N - j - 1; the prefill tap (last prompt token) is labelled
    N - 1; the prompt-probe input is the mean embedding-layer hidden over
    prompt positions, labelled N.
    """
    cfg = MODEL
    del max_steps  # kept for API compatibility
    t_max = max(len(r.prompt) + len(r.response) for r in requests)

    dx, dy, drem, dreq, dt = [], [], [], [], []
    px, py, pn = [], [], []

    for lo in range(0, len(requests), batch_size):
        batch = requests[lo:lo + batch_size]
        bsz = len(batch)
        seqs_np = np.zeros((bsz, t_max), dtype=np.int32)
        plens = np.array([len(r.prompt) for r in batch], dtype=np.int32)
        for i, r in enumerate(batch):
            full = r.prompt + r.response
            seqs_np[i, :len(full)] = full
        hid, _ = M.full_forward(params, jnp.asarray(seqs_np))  # [B, T, L+1, D]
        hid = np.asarray(hid)
        for i, r in enumerate(batch):
            p, n = int(plens[i]), r.true_output_len
            # Prompt probe sample: mean embedding-layer hidden over prompt.
            px.append(hid[i, :p, 0, :].mean(axis=0))
            py.append(BINS.bin_of(n))
            pn.append(n)
            # Iteration taps: j = 0 is the prefill step (input = last prompt
            # token, produced output token 1, remaining n-1) and j >= 1 are
            # decode steps (input = output token j at position p+j-1).
            for j in range(n):
                pos = p - 1 + j
                rem = n - j - 1
                dx.append(hid[i, pos, :, :])     # [L+1, D]
                dy.append(BINS.bin_of(rem))
                drem.append(rem)
                dreq.append(r.rid)
                dt.append(j)

    return ProfileData(
        decode_x=np.asarray(dx, dtype=np.float32),
        decode_y=np.asarray(dy, dtype=np.int64),
        decode_rem=np.asarray(drem, dtype=np.float64),
        decode_req=np.asarray(dreq, dtype=np.int64),
        decode_t=np.asarray(dt, dtype=np.int64),
        prompt_x=np.asarray(px, dtype=np.float32),
        prompt_y=np.asarray(py, dtype=np.int64),
        prompt_n=np.asarray(pn, dtype=np.float64),
    )


# ---------------------------------------------------------------------------
# Training (hand-rolled Adam; probes for all tap points trained jointly
# via vmap over the tap axis)
# ---------------------------------------------------------------------------

def _init_probe(key, d, hidden, k):
    k1, k2 = jax.random.split(key)
    s1 = 1.0 / math.sqrt(d)
    s2 = 1.0 / math.sqrt(hidden)
    return {
        "w1": jax.random.uniform(k1, (d, hidden), minval=-s1, maxval=s1),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.uniform(k2, (hidden, k), minval=-s2, maxval=s2),
        "b2": jnp.zeros((k,)),
    }


def _probe_logits(p, x):
    h = jnp.maximum(x @ p["w1"] + p["b1"], 0.0)
    return h @ p["w2"] + p["b2"]


def _ce_loss(p, x, y, wd):
    logits = _probe_logits(p, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, y[:, None], 1)[:, 0] - logz
    l2 = sum(jnp.sum(v * v) for k, v in p.items() if k.startswith("w"))
    return -jnp.mean(ll) + wd * l2


@functools.partial(jax.jit, static_argnames=("lr_max", "total_steps", "wd"))
def _adam_step(p, m, v, step, x, y, *, lr_max, total_steps, wd):
    """One AdamW-ish step with cosine-annealed lr (paper: AdamW + cosine)."""
    g = jax.grad(_ce_loss)(p, x, y, wd)
    b1, b2, eps = 0.9, 0.999, 1e-8
    lr = 0.5 * lr_max * (1.0 + jnp.cos(jnp.pi * step / total_steps))
    m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
    v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
    mh = jax.tree.map(lambda mm: mm / (1 - b1 ** (step + 1)), m)
    vh = jax.tree.map(lambda vv: vv / (1 - b2 ** (step + 1)), v)
    p = jax.tree.map(lambda pp, mm, vv: pp - lr * mm / (jnp.sqrt(vv) + eps),
                     p, mh, vh)
    return p, m, v


def train_probe(x: np.ndarray, y: np.ndarray, seed: int = 0,
                hidden: int = None, steps: int = None,
                batch: int = None) -> Dict[str, np.ndarray]:
    """Train one probe (or a stack: x may be [n, D] or [n, T, D] for T
    probes trained jointly via vmap)."""
    hidden = hidden or PROBE.hidden
    steps = steps or PROBE.train_steps_cap
    batch = batch or PROBE.batch_size
    k_bins = BINS.n_bins
    stacked = x.ndim == 3
    d = x.shape[-1]
    key = jax.random.PRNGKey(seed)

    if stacked:
        t = x.shape[1]
        keys = jax.random.split(key, t)
        p = jax.vmap(lambda kk: _init_probe(kk, d, hidden, k_bins))(keys)
        step_fn = jax.vmap(
            functools.partial(_adam_step, lr_max=PROBE.lr, total_steps=steps,
                              wd=PROBE.weight_decay),
            in_axes=(0, 0, 0, None, 1, None))
    else:
        p = _init_probe(key, d, hidden, k_bins)
        step_fn = functools.partial(_adam_step, lr_max=PROBE.lr,
                                    total_steps=steps, wd=PROBE.weight_decay)

    m = jax.tree.map(jnp.zeros_like, p)
    v = jax.tree.map(jnp.zeros_like, p)
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    xj = jnp.asarray(x)
    yj = jnp.asarray(y)
    for s in range(steps):
        idx = rng.integers(0, n, size=batch)
        p, m, v = step_fn(p, m, v, s, xj[idx], yj[idx])
    return jax.tree.map(np.asarray, p)


def probe_predict(p: Dict[str, np.ndarray], x: np.ndarray) -> np.ndarray:
    """Softmax probabilities from a trained probe (numpy)."""
    h = np.maximum(x @ p["w1"] + p["b1"], 0.0)
    logits = h @ p["w2"] + p["b2"]
    logits -= logits.max(axis=-1, keepdims=True)
    e = np.exp(logits)
    return e / e.sum(axis=-1, keepdims=True)


# ---------------------------------------------------------------------------
# Evaluation (Fig 2 / Fig 3)
# ---------------------------------------------------------------------------

def expected_length(probs: np.ndarray) -> np.ndarray:
    mids = np.asarray(BINS.midpoints)
    return probs @ mids


def eval_layers(data: ProfileData, probes, prompt_probe,
                val_req_ids: set) -> List[dict]:
    """Per-tap-point MAE, refined and unrefined, plus the prompt-probe
    ("BERT") baseline — the series of Figures 2 and 3."""
    sel = np.isin(data.decode_req, list(val_req_ids))
    rows = []
    n_taps = data.decode_x.shape[1]

    # Prompt-probe baseline: one static prediction, minus tokens generated.
    prompt_ids = np.asarray(sorted(val_req_ids))
    # map rid -> predicted total
    rid_list = list(range(len(data.prompt_x)))
    p_probs = probe_predict(prompt_probe, data.prompt_x)
    p_len = expected_length(p_probs)
    bert_pred = {rid: p_len[rid] for rid in rid_list}
    bert_err = []
    for rid in prompt_ids:
        mask = (data.decode_req == rid)
        ts = data.decode_t[mask]
        rem = data.decode_rem[mask]
        pred = np.maximum(bert_pred[rid] - (ts + 1), 0.0)
        bert_err.append(np.abs(pred - rem))
    bert_mae = float(np.concatenate(bert_err).mean())

    for tap in range(n_taps):
        probs = probe_predict(
            jax.tree.map(lambda a: a[tap], probes), data.decode_x[sel][:, tap, :])
        raw_pred = expected_length(probs)
        raw_mae = float(np.abs(raw_pred - data.decode_rem[sel]).mean())

        # Refined: run the Bayesian smoother per request over its sequence.
        refined_err = []
        reqs = data.decode_req[sel]
        rems = data.decode_rem[sel]
        order = np.argsort(data.decode_t[sel], kind="stable")
        for rid in np.unique(reqs):
            rmask = reqs == rid
            p_seq = probs[rmask]
            r_seq = rems[rmask]
            t_seq = data.decode_t[sel][rmask]
            srt = np.argsort(t_seq)
            preds = smooth_sequence(p_seq[srt])
            refined_err.append(np.abs(preds - r_seq[srt]))
        refined_mae = float(np.concatenate(refined_err).mean())
        rows.append({"layer": tap, "mae_raw": raw_mae, "mae_refined": refined_mae,
                     "mae_bert": bert_mae})
    return rows


# ---------------------------------------------------------------------------
# Entry point used by aot.py
# ---------------------------------------------------------------------------

def run(params, outdir: str, n_requests: int = None, train_steps: int = None,
        verbose: bool = True) -> dict:
    n_requests = n_requests or PROBE.n_profile_requests
    requests = gen_requests(n_requests, WORKLOAD.train_seed)
    n_val = max(int(n_requests * PROBE.val_frac), 8)
    val_ids = set(r.rid for r in requests[-n_val:])

    if verbose:
        print(f"[probe] profiling {n_requests} requests…", flush=True)
    data = profile_requests(params, requests)
    if verbose:
        print(f"[probe] {len(data.decode_y)} iteration pairs, "
              f"{len(data.prompt_y)} prompt pairs", flush=True)

    train_sel = ~np.isin(data.decode_req, list(val_ids))
    if verbose:
        print("[probe] training per-layer probes…", flush=True)
    probes = train_probe(data.decode_x[train_sel], data.decode_y[train_sel],
                         steps=train_steps)
    prompt_train = np.asarray([i for i in range(n_requests) if i not in val_ids])
    prompt_probe = train_probe(data.prompt_x[prompt_train],
                               data.prompt_y[prompt_train], seed=1,
                               steps=train_steps)

    if verbose:
        print("[probe] evaluating…", flush=True)
    rows = eval_layers(data, probes, prompt_probe, val_ids)
    best = min(rows, key=lambda r: r["mae_refined"])
    if verbose:
        for r in rows:
            print(f"[probe] layer {r['layer']:2d}  raw {r['mae_raw']:7.2f}  "
                  f"refined {r['mae_refined']:7.2f}  (bert {r['mae_bert']:.2f})",
                  flush=True)
        print(f"[probe] best tap layer: {best['layer']}", flush=True)

    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, "fig2_mae_by_layer.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=["layer", "mae_raw", "mae_refined",
                                          "mae_bert"])
        w.writeheader()
        w.writerows(rows)

    weights = {
        "hidden": PROBE.hidden,
        "best_layer": best["layer"],
        "bert_mae": rows[0]["mae_bert"],
        # Embedding table [V, D] row-major: lets the Rust coordinator
        # compute the mean layer-0 prompt embedding natively at admission
        # (the paper's BERT predictor also runs before any LLM compute).
        "embed": np.asarray(params["embed"]).reshape(-1).tolist(),
        "layers": [
            {k: np.asarray(jax.tree.map(lambda a: a[t], probes)[k]).reshape(-1).tolist()
             for k in ("w1", "b1", "w2", "b2")}
            for t in range(data.decode_x.shape[1])
        ],
        "prompt": {k: np.asarray(prompt_probe[k]).reshape(-1).tolist()
                   for k in ("w1", "b1", "w2", "b2")},
        "mae_by_layer": rows,
    }
    with open(os.path.join(outdir, "probe_weights.json"), "w") as f:
        json.dump(weights, f)
    return weights
