"""Write the cross-language workload fixture embedded in the Rust crate.

``rust/src/workload/golden_fixture.json`` pins the SplitMix64 stream and
the first requests of the seed-12345 generator stream against this
Python reference — the same vectors ``aot.py`` puts in
``artifacts/golden.json``, but checked in, so the parity test runs from
a fresh checkout with no ``make artifacts`` (ROADMAP "Python↔Rust
goldens" follow-on).

    cd python && python -m compile.fixture
"""

import json
import os

from .workload import golden_vectors

OUT = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "src", "workload", "golden_fixture.json"
)


def main() -> None:
    vectors = golden_vectors()
    path = os.path.normpath(OUT)
    with open(path, "w") as f:
        json.dump(vectors, f, sort_keys=True, indent=1)
        f.write("\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
