"""SplitMix64 PRNG — bit-identical mirror of ``rust/src/util/rng.rs``.

The workload generator must produce identical streams in the Python
profiling/training path and the Rust serving path; this is enforced by
golden-vector tests on both sides (``python/tests/test_workload.py`` and
``rust/src/util/rng.rs`` unit tests share ``artifacts/golden.json``).
"""

MASK64 = (1 << 64) - 1


class SplitMix64:
    """Sebastiano Vigna's SplitMix64; tiny, fast, and trivially portable."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53 bits of entropy."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_range(self, lo: int, hi: int) -> int:
        """Uniform integer in [lo, hi] (inclusive), via modulo reduction.

        Modulo bias is negligible for our ranges (<< 2^32) and keeping the
        reduction trivial makes the Rust mirror easy to verify.
        """
        assert hi >= lo
        span = hi - lo + 1
        return lo + (self.next_u64() % span)

    def split(self) -> "SplitMix64":
        """Derive an independent child stream (used per-request)."""
        return SplitMix64(self.next_u64())


def erfinv(x: float) -> float:
    """Inverse error function (Winitzki's approximation, ~1e-3 rel err).

    Used only to convert uniforms into normals for the log-normal length
    sampler; both languages use this same approximation so streams match
    exactly. Accuracy is irrelevant here — any fixed monotone map from
    U(0,1) to a heavy-tailed length distribution serves the workload.
    """
    import math

    a = 0.147
    s = 1.0 if x >= 0 else -1.0
    x = min(max(x, -0.999999), 0.999999)
    ln1mx2 = math.log(1.0 - x * x)
    t1 = 2.0 / (math.pi * a) + ln1mx2 / 2.0
    return s * math.sqrt(math.sqrt(t1 * t1 - ln1mx2 / a) - t1)


def normal_from_uniform(u: float) -> float:
    """Standard normal via inverse-CDF: N^{-1}(u) = sqrt(2) * erfinv(2u-1)."""
    import math

    return math.sqrt(2.0) * erfinv(2.0 * u - 1.0)
