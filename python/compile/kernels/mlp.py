"""Pallas probe-predictor MLP kernel (L1).

The paper's length predictor is a 2-layer MLP run every decode iteration
(and in large batches for Table 1). On TPU this is one fused VMEM-resident
pass per batch tile: relu(x@W1+b1)@W2+b2 -> softmax, tiled over the batch
so a tile's activations ([TILE, D] + [TILE, Hd]) stay in VMEM and each
grid step is a pair of MXU contractions — instead of the paper's two CUDA
kernel launches + softmax.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_TILE = 128


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]                       # [T, D]
    h = jnp.maximum(x @ w1_ref[...] + b1_ref[...], 0.0)
    logits = h @ w2_ref[...] + b2_ref[...]
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def predictor_mlp(x, w1, b1, w2, b2, *, batch_tile=BATCH_TILE, interpret=True):
    """Fused probe MLP. Same contract as ``ref.predictor_mlp_ref``.

    x: [N, D] -> [N, K]. N is padded to a multiple of the tile internally.
    """
    n, d = x.shape
    hd = w1.shape[1]
    k = w2.shape[1]
    tile = min(batch_tile, n)
    pad = (-n) % tile
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    np_ = n + pad
    out = pl.pallas_call(
        _mlp_kernel,
        grid=(np_ // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((d, hd), lambda i: (0, 0)),
            pl.BlockSpec((hd,), lambda i: (0,)),
            pl.BlockSpec((hd, k), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, k), x.dtype),
        interpret=interpret,
    )(xp, w1, b1, w2, b2)
    return out[:n] if pad else out
