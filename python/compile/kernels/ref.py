"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has an exact (up to float associativity)
reference here; ``python/tests/test_kernels.py`` sweeps shapes/dtypes with
hypothesis and asserts allclose between kernel and oracle.
"""

import jax.numpy as jnp


def decode_attention_ref(q, k, v, lens):
    """Single-token attention against a per-slot KV cache.

    Args:
      q:    [B, H, Dh]     query for the current token of each slot.
      k, v: [B, H, S, Dh]  per-slot KV cache (garbage beyond ``lens``).
      lens: [B] int32      number of valid cache positions per slot
                           (the current token's KV must already be written).
    Returns:
      [B, H, Dh] attention output.
    """
    s = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bhd,bhsd->bhs", q, k) * scale
    mask = jnp.arange(s)[None, None, :] < lens[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    # Guard fully-masked rows (inactive slots): softmax of all -inf -> 0.
    m = jnp.max(scores, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(mask, jnp.exp(scores - m), 0.0)
    z = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / jnp.maximum(z, 1e-30)
    return jnp.einsum("bhs,bhsd->bhd", probs, v)


def prefill_attention_ref(q, k, v, q_pos, lens):
    """Chunked-prefill attention: C queries attend causally to the cache.

    Args:
      q:     [C, H, Dh]   chunk queries (one slot).
      k, v:  [H, S, Dh]   that slot's cache, chunk KV already written.
      q_pos: [C] int32    absolute position of each query token.
      lens:  int32        valid cache length (= start + n_valid).
    Returns:
      [C, H, Dh]
    """
    s = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("chd,hsd->chs", q, k) * scale
    key_pos = jnp.arange(s)[None, None, :]
    mask = (key_pos <= q_pos[:, None, None]) & (key_pos < lens)
    scores = jnp.where(mask, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(mask, jnp.exp(scores - m), 0.0)
    z = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / jnp.maximum(z, 1e-30)
    return jnp.einsum("chs,hsd->chd", probs, v)


def predictor_mlp_ref(x, w1, b1, w2, b2):
    """Probe MLP: softmax(relu(x@w1+b1)@w2+b2).

    Args:
      x:  [N, D] embeddings.
      w1: [D, Hd], b1: [Hd], w2: [Hd, K], b2: [K].
    Returns:
      [N, K] bin probabilities.
    """
    h = jnp.maximum(x @ w1 + b1, 0.0)
    logits = h @ w2 + b2
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)
