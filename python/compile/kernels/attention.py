"""Pallas attention kernels (L1) — the paper's serving hot spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA decode
attention of the paper's testbed (one warp per head streaming KV pages
from HBM) is re-thought for the TPU memory hierarchy Pallas exposes:

* the grid is over batch slots — BlockSpec stages one slot's KV
  (``[H, S, Dh]`` = 80 KiB at the default config) from HBM into VMEM per
  grid step;
* inside the kernel an *online-softmax* loop walks the sequence in tiles
  of ``SEQ_TILE`` so the working set per tile stays MXU-shaped
  (``[H, tile] x [H, tile, Dh]`` contractions) and the kernel scales to
  caches larger than VMEM by shrinking the staged block;
* sequence-length masking replaces the page table: slots are fixed-stride
  so the HBM<->VMEM schedule is entirely static.

All kernels are lowered with ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls); correctness is asserted against
``ref.py`` and real-TPU efficiency is *estimated* from the block shapes in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sequence tile for the in-kernel online-softmax loop. 64 keys x 4 heads x
# 16 dims x 4 B = 16 KiB staged per tile step — comfortably double-
# bufferable in VMEM while keeping the contraction MXU-friendly.
SEQ_TILE = 64

NEG_BIG = -1e30


def _online_softmax_tiles(q, k, v, valid_len, seq_tile):
    """Shared online-softmax accumulation over sequence tiles.

    q: [H, Dh]; k, v: [H, S, Dh]; valid_len: scalar int32.
    Returns [H, Dh]. Tiles are unrolled (S and seq_tile are static).
    """
    h, s, dh = k.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    m = jnp.full((h, 1), NEG_BIG, q.dtype)      # running max
    l = jnp.zeros((h, 1), q.dtype)              # running sum-exp
    acc = jnp.zeros((h, dh), q.dtype)           # running weighted sum
    n_tiles = (s + seq_tile - 1) // seq_tile
    for t in range(n_tiles):
        lo = t * seq_tile
        kt = k[:, lo:lo + seq_tile, :]           # [H, T, Dh]
        vt = v[:, lo:lo + seq_tile, :]
        scores = jnp.einsum("hd,htd->ht", q, kt) * scale
        idx = lo + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        in_len = idx < valid_len
        scores = jnp.where(in_len, scores, NEG_BIG)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        # Explicit mask: when every key so far is invalid, m_new == NEG_BIG
        # and exp(scores - m_new) would be 1, not 0.
        p = jnp.where(in_len, jnp.exp(scores - m_new), 0.0)
        # Renormalise the running state and fold in this tile.
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("ht,htd->hd", p, vt)
        m = m_new
    return acc / jnp.maximum(l, 1e-30)


def _decode_kernel(q_ref, k_ref, v_ref, lens_ref, o_ref, *, seq_tile):
    q = q_ref[0]                                  # [H, Dh]
    k = k_ref[0]                                  # [H, S, Dh]
    v = v_ref[0]
    valid = lens_ref[0]
    o_ref[0] = _online_softmax_tiles(q, k, v, valid, seq_tile)


def decode_attention(q, k, v, lens, *, seq_tile=SEQ_TILE, interpret=True,
                     grid_mode="fused"):
    """Pallas decode attention. Same contract as ``ref.decode_attention_ref``.

    q: [B, H, Dh]; k, v: [B, H, S, Dh]; lens: [B] int32 -> [B, H, Dh].

    ``grid_mode``:
      * ``"slots"`` — grid over batch slots; each grid step stages one
        slot's KV block into VMEM. This is the shape a real-TPU Mosaic
        lowering would use (one slot's KV = 80 KiB per step).
      * ``"fused"`` (default) — a single grid step with the batch
        vectorised inside the kernel and the same online-softmax tile
        loop over the sequence. Numerically identical; on the CPU
        *interpreter* (the only executor available here) it avoids the
        per-grid-step interpretation overhead, halving the serving
        decode cost (EXPERIMENTS.md §Perf L1).
    """
    b, h, dh = q.shape
    s = k.shape[2]
    if grid_mode == "slots":
        kernel = functools.partial(_decode_kernel, seq_tile=seq_tile)
        return pl.pallas_call(
            kernel,
            grid=(b,),
            in_specs=[
                pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, h, s, dh), lambda i: (i, 0, 0, 0)),
                pl.BlockSpec((1, h, s, dh), lambda i: (i, 0, 0, 0)),
                pl.BlockSpec((1,), lambda i: (i,)),
            ],
            out_specs=pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
            interpret=interpret,
        )(q, k, v, lens)
    kernel = functools.partial(_decode_kernel_fused, seq_tile=seq_tile)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, h, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((b, h, s, dh), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((b, h, s, dh), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b, h, dh), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, lens)


def _decode_kernel_fused(q_ref, k_ref, v_ref, lens_ref, o_ref, *, seq_tile):
    """Batch-vectorised online-softmax decode kernel (single grid step)."""
    q = q_ref[...]                                # [B, H, Dh]
    lens = lens_ref[...]                          # [B]
    b, h, dh = q.shape
    s = k_ref.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    m = jnp.full((b, h, 1), NEG_BIG, q.dtype)
    l = jnp.zeros((b, h, 1), q.dtype)
    acc = jnp.zeros((b, h, dh), q.dtype)
    n_tiles = (s + seq_tile - 1) // seq_tile
    for t in range(n_tiles):
        lo = t * seq_tile
        kt = k_ref[:, :, lo:lo + seq_tile, :]      # [B, H, T, Dh]
        vt = v_ref[:, :, lo:lo + seq_tile, :]
        scores = jnp.einsum("bhd,bhtd->bht", q, kt) * scale
        idx = lo + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 2)
        in_len = idx < lens[:, None, None]
        scores = jnp.where(in_len, scores, NEG_BIG)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(in_len, jnp.exp(scores - m_new), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bht,bhtd->bhd", p, vt)
        m = m_new
    o_ref[...] = acc / jnp.maximum(l, 1e-30)


def _prefill_kernel(q_ref, k_ref, v_ref, qpos_ref, lens_ref, o_ref, *, seq_tile):
    # One grid step per chunk query token; heads vectorised inside.
    q = q_ref[0]                                  # [H, Dh]
    k = k_ref[...]                                # [H, S, Dh] (full block)
    v = v_ref[...]
    qp = qpos_ref[0]
    valid = jnp.minimum(qp + 1, lens_ref[0])      # causal AND length mask
    o_ref[0] = _online_softmax_tiles(q, k, v, valid, seq_tile)


def prefill_attention(q, k, v, q_pos, lens, *, seq_tile=SEQ_TILE, interpret=True,
                      grid_mode="tokens"):
    """Pallas chunked-prefill attention for a single slot.

    q: [C, H, Dh]; k, v: [H, S, Dh]; q_pos: [C] int32; lens: scalar int32
    (broadcast to [1] for the kernel) -> [C, H, Dh].

    ``grid_mode`` as in `decode_attention`: "tokens" (default) grids over
    the chunk tokens; "fused" vectorises the chunk inside one grid step.
    Unlike decode, the tokens grid measured *faster* under the CPU
    interpreter (2.1 ms vs 16.7 ms per chunk) — kept as default
    (EXPERIMENTS.md §Perf L1).
    """
    c, h, dh = q.shape
    s = k.shape[1]
    lens_arr = jnp.reshape(lens.astype(jnp.int32), (1,))
    if grid_mode == "tokens":
        kernel = functools.partial(_prefill_kernel, seq_tile=seq_tile)
        return pl.pallas_call(
            kernel,
            grid=(c,),
            in_specs=[
                pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
                pl.BlockSpec((h, s, dh), lambda i: (0, 0, 0)),
                pl.BlockSpec((h, s, dh), lambda i: (0, 0, 0)),
                pl.BlockSpec((1,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((c, h, dh), q.dtype),
            interpret=interpret,
        )(q, k, v, q_pos.astype(jnp.int32), lens_arr)
    kernel = functools.partial(_prefill_kernel_fused, seq_tile=seq_tile)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((c, h, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((h, s, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((h, s, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((c, h, dh), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, q_pos.astype(jnp.int32), lens_arr)


def _prefill_kernel_fused(q_ref, k_ref, v_ref, qpos_ref, lens_ref, o_ref, *, seq_tile):
    """Chunk-vectorised online-softmax prefill kernel (one grid step)."""
    q = q_ref[...]                                # [C, H, Dh]
    qp = qpos_ref[...]                            # [C]
    valid = jnp.minimum(qp + 1, lens_ref[0])      # causal AND length mask
    c, h, dh = q.shape
    s = k_ref.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    m = jnp.full((c, h, 1), NEG_BIG, q.dtype)
    l = jnp.zeros((c, h, 1), q.dtype)
    acc = jnp.zeros((c, h, dh), q.dtype)
    n_tiles = (s + seq_tile - 1) // seq_tile
    for t in range(n_tiles):
        lo = t * seq_tile
        kt = k_ref[:, lo:lo + seq_tile, :]        # [H, T, Dh]
        vt = v_ref[:, lo:lo + seq_tile, :]
        scores = jnp.einsum("chd,htd->cht", q, kt) * scale
        idx = lo + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 2)
        in_len = idx < valid[:, None, None]
        scores = jnp.where(in_len, scores, NEG_BIG)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(in_len, jnp.exp(scores - m_new), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("cht,htd->chd", p, vt)
        m = m_new
    o_ref[...] = acc / jnp.maximum(l, 1e-30)
