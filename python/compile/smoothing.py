"""Bayesian refinement of per-iteration bin predictions (paper §3.1 +
Appendix A) — mirrored by ``rust/src/predictor/smoothing.rs``.

The prior drifts one bin downward as tokens are generated (remaining
length shrinks): with equal-width bins of size ``w`` and a uniform
within-bin assumption, a value stays in its bin w.p. 1 - 1/w and moves to
the next-lower bin w.p. 1/w per generated token. The transition matrix is
therefore lower-bidiagonal (Appendix A):

    T[i, i]   = 1 - 1/w
    T[i, i+1] = 1/w        (B_{i+1} -> B_i)

Update per iteration t with classifier output p^(t):

    q_prior = T @ q^(t-1)
    q^(t)(i) ∝ q_prior(i) * p^(t)(i)
"""

import numpy as np

from .config import BINS, BinConfig


def transition_matrix(b: BinConfig = BINS) -> np.ndarray:
    k = b.n_bins
    w = b.width
    t = np.zeros((k, k), dtype=np.float64)
    for i in range(k):
        t[i, i] = 1.0 - 1.0 / w
        if i + 1 < k:
            t[i, i + 1] = 1.0 / w
    # Bin 0 absorbs: once a request is in the lowest bin it stays there.
    t[0, 0] = 1.0 - 1.0 / w  # mass leaks only via normalisation; keep form
    return t


class BayesianSmoother:
    """Per-request probability state over remaining-length bins."""

    def __init__(self, b: BinConfig = BINS):
        self.bins = b
        self.t = transition_matrix(b)
        self.q = None

    def reset(self, p0: np.ndarray):
        # A massless or non-finite row (a NaN sum fails every
        # comparison) falls back to the uniform prior instead of leaving
        # a poisoned state. Keep in sync with
        # rust/src/predictor/smoothing.rs.
        self.q = np.asarray(p0, dtype=np.float64)
        s = self.q.sum()
        if np.isfinite(s) and s > 0:
            self.q = self.q / s
        else:
            k = max(len(self.q), 1)
            self.q = np.full(len(self.q), 1.0 / k)

    def update(self, p: np.ndarray) -> np.ndarray:
        assert self.q is not None, "reset() before update()"
        prior = self.t @ self.q
        post = prior * np.asarray(p, dtype=np.float64)
        s = post.sum()
        if not (np.isfinite(s) and s > 1e-30):
            # Degenerate disagreement (or a non-finite classifier row):
            # fall back to the raw classifier, and to uniform when that
            # has no mass either.
            post = np.asarray(p, dtype=np.float64)
            s = post.sum()
            if not (np.isfinite(s) and s > 1e-30):
                post = np.ones(len(self.q))
                s = post.sum()
        self.q = post / s
        return self.q

    def predicted_length(self) -> float:
        mids = np.asarray(self.bins.midpoints)
        return float(np.dot(self.q, mids))


def smooth_sequence(p_seq: np.ndarray, b: BinConfig = BINS) -> np.ndarray:
    """Vectorised refinement over a [T, K] sequence of classifier outputs;
    returns [T] predicted remaining lengths. Used for Fig 3 evaluation."""
    sm = BayesianSmoother(b)
    sm.reset(p_seq[0])
    out = np.empty(len(p_seq))
    out[0] = sm.predicted_length()
    for i in range(1, len(p_seq)):
        sm.update(p_seq[i])
        out[i] = sm.predicted_length()
    return out
