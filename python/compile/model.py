"""TrailLM (L2) — a small Llama-style transformer expressed as a
packed-state step machine.

Architecture (paper substitution table, DESIGN.md §2): RMSNorm + RoPE +
multi-head attention + SwiGLU, pre-norm residual blocks — the same family
as the paper's Llama3-8B-Instruct, scaled to ~0.4M parameters so a CPU
PJRT backend sustains the serving loop.

Three graphs are AOT-lowered for the Rust runtime (see ``aot.py``):

* ``decode_step(state, tokens, pos, active) -> state`` — one iteration for
  all B slots; KV written in-place (masked), logits + all-layer probe taps
  stored into the state tensor.
* ``prefill_chunk(state, tokens, slot, start, nvalid) -> state`` — one
  chunk of one slot's prompt; accumulates per-layer prompt-tap sums.
* ``readout(state) -> (logits, taps, prompt_taps, argmax)`` — the only
  graph that returns host-visible values; everything heavy stays on
  device (DESIGN.md §1, packed-state design).

The pure-jnp batch paths at the bottom (``full_forward``,
``generate_batch``) are used by the probe profiler and by tests as an
independent oracle for the step graphs.
"""

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from .config import LAYOUT, MODEL, ModelConfig, StateLayout, make_layout
from .kernels import attention as attn_k
from .kernels import mlp as mlp_k
from .kernels import ref as kref

EPS = 1e-5


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig = MODEL) -> Dict[str, jnp.ndarray]:
    """Fixed, seeded random weights. The model is a *substrate*: scheduling
    phenomena depend on the autoregressive loop structure, not on trained
    weights (DESIGN.md §2). Weights are baked into the HLO as constants."""
    key = jax.random.PRNGKey(cfg.weight_seed)
    ks = jax.random.split(key, 4 + 8 * cfg.n_layers)
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.n_heads * cfg.d_head
    w = 0.08

    def nrm(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    params = {
        "embed": nrm(ks[0], (cfg.vocab, d), 0.5),
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    for l in range(cfg.n_layers):
        o = 4 + 8 * l
        params[f"l{l}.attn_norm"] = jnp.ones((d,), jnp.float32)
        params[f"l{l}.wq"] = nrm(ks[o + 0], (d, hd), w)
        params[f"l{l}.wk"] = nrm(ks[o + 1], (d, hd), w)
        params[f"l{l}.wv"] = nrm(ks[o + 2], (d, hd), w)
        params[f"l{l}.wo"] = nrm(ks[o + 3], (hd, d), w)
        params[f"l{l}.ffn_norm"] = jnp.ones((d,), jnp.float32)
        params[f"l{l}.wg"] = nrm(ks[o + 4], (d, f), w)
        params[f"l{l}.wu"] = nrm(ks[o + 5], (d, f), w)
        params[f"l{l}.wd"] = nrm(ks[o + 6], (f, d), w)
    return params


def param_count(cfg: ModelConfig = MODEL) -> int:
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.n_heads * cfg.d_head
    per_layer = 2 * d + 3 * d * hd + hd * d + 2 * d * f + f * d
    return cfg.vocab * d + d + cfg.n_layers * per_layer


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, scale):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + EPS) * scale


def silu(x):
    return x * jax.nn.sigmoid(x)


def rope(x, pos, cfg: ModelConfig = MODEL):
    """Rotary embedding. x: [..., H, Dh], pos broadcastable to x[..., 0, 0]."""
    dh = cfg.d_head
    half = dh // 2
    inv_freq = cfg.rope_theta ** (-jnp.arange(half, dtype=jnp.float32) * 2.0 / dh)
    ang = pos[..., None, None].astype(jnp.float32) * inv_freq  # [..., 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(x, params, l, cfg):
    """x: [..., D] -> q, k, v each [..., H, Dh]."""
    shape = x.shape[:-1] + (cfg.n_heads, cfg.d_head)
    q = (x @ params[f"l{l}.wq"]).reshape(shape)
    k = (x @ params[f"l{l}.wk"]).reshape(shape)
    v = (x @ params[f"l{l}.wv"]).reshape(shape)
    return q, k, v


def _ffn(x, params, l):
    return (silu(x @ params[f"l{l}.wg"]) * (x @ params[f"l{l}.wu"])) @ params[f"l{l}.wd"]


# ---------------------------------------------------------------------------
# Packed-state helpers
# ---------------------------------------------------------------------------

def kv_shape(cfg: ModelConfig):
    return (cfg.n_layers, 2, cfg.batch_slots, cfg.n_heads, cfg.max_seq, cfg.d_head)


def unpack_kv(state, cfg: ModelConfig, lay: StateLayout):
    return state[lay.kv_off:lay.kv_off + lay.kv_len].reshape(kv_shape(cfg))


def pack_regions(state, lay: StateLayout, *, kv=None, logits=None, taps=None,
                 ptap=None, pcnt=None):
    """Rebuild the flat state with the given regions replaced."""
    parts = []
    for arr, off, ln in (
        (kv, lay.kv_off, lay.kv_len),
        (logits, lay.logits_off, lay.logits_len),
        (taps, lay.taps_off, lay.taps_len),
        (ptap, lay.ptap_off, lay.ptap_len),
        (pcnt, lay.pcnt_off, lay.pcnt_len),
    ):
        parts.append(state[off:off + ln] if arr is None else arr.reshape(-1))
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# Decode step graph
# ---------------------------------------------------------------------------

def _decode_attn(q, k, v, lens, use_pallas):
    if use_pallas:
        return attn_k.decode_attention(q, k, v, lens)
    return kref.decode_attention_ref(q, k, v, lens)


def make_decode_step(params, cfg: ModelConfig = MODEL, lay: StateLayout = LAYOUT,
                     use_pallas: bool = True) -> Callable:
    """decode_step(state, tokens[B] i32, pos[B] i32, active[B] f32) -> state.

    ``pos[b]`` is the absolute position of the *input* token; its KV is
    written at ``pos[b]`` and attention sees positions [0, pos[b]]. Inactive
    slots (active==0) neither write KV nor disturb anything: their KV write
    is masked out and their lens is 0 (attention output 0, logits garbage
    that Rust ignores).
    """
    b, s = cfg.batch_slots, cfg.max_seq

    hsd = cfg.n_heads * s * cfg.d_head

    def write_kv_slot(state, layer, which, slot, pos_b, vec, act_b):
        """Donation-friendly KV write: one [H, 1, Dh] block at
        (layer, which, slot, :, pos_b, :) of the packed state. Inactive
        slots keep the old value (read-modify-write of just the block)."""
        base = lay.kv_off + ((layer * 2 + which) * b + slot) * hsd
        # base is static (python ints): a static slice fuses better than
        # dynamic_slice; only the position within the slot is dynamic.
        kv3 = state[base:base + hsd].reshape(cfg.n_heads, s, cfg.d_head)
        old = jax.lax.dynamic_slice(kv3, (0, pos_b, 0), (cfg.n_heads, 1, cfg.d_head))
        new = jnp.where(act_b > 0, vec[:, None, :], old)
        kv3 = jax.lax.dynamic_update_slice(kv3, new, (0, pos_b, 0))
        return jax.lax.dynamic_update_slice(state, kv3.reshape(-1), (base,))

    def step(state, tokens, pos, active):
        x = params["embed"][tokens]                       # [B, D]
        taps = [x]
        lens = jnp.where(active > 0, pos + 1, 0).astype(jnp.int32)
        for l in range(cfg.n_layers):
            h = rmsnorm(x, params[f"l{l}.attn_norm"])
            q, k, v = _qkv(h, params, l, cfg)             # [B, H, Dh]
            q = rope(q, pos, cfg)
            k = rope(k, pos, cfg)
            # Per-slot DUS writes — with the state buffer donated these
            # are in-place updates, not a 10.5 MB rewrite per step.
            for slot in range(b):
                state = write_kv_slot(state, l, 0, slot, pos[slot], k[slot], active[slot])
                state = write_kv_slot(state, l, 1, slot, pos[slot], v[slot], active[slot])
            lbase = lay.kv_off + l * 2 * b * hsd
            lkv = state[lbase:lbase + 2 * b * hsd].reshape(
                2, b, cfg.n_heads, s, cfg.d_head)
            out = _decode_attn(q, lkv[0], lkv[1], lens, use_pallas)    # [B,H,Dh]
            x = x + out.reshape(b, -1) @ params[f"l{l}.wo"]
            x = x + _ffn(rmsnorm(x, params[f"l{l}.ffn_norm"]), params, l)
            taps.append(x)
        logits = rmsnorm(x, params["final_norm"]) @ params["embed"].T  # [B, V]
        # Inactive slots must keep their previous logits/taps: a slot
        # whose prefill completed this iteration carries its first-token
        # logits there, and the decode step must not clobber them.
        old_logits = state[lay.logits_off:lay.logits_off + lay.logits_len].reshape(b, -1)
        old_taps = state[lay.taps_off:lay.taps_off + lay.taps_len].reshape(
            cfg.n_taps, b, cfg.d_model)
        am = active[:, None]
        logits = logits * am + old_logits * (1.0 - am)
        new_taps = jnp.stack(taps) * am[None] + old_taps * (1.0 - am[None])
        state = jax.lax.dynamic_update_slice(state, logits.reshape(-1), (lay.logits_off,))
        state = jax.lax.dynamic_update_slice(state, new_taps.reshape(-1), (lay.taps_off,))
        return state

    return step


# ---------------------------------------------------------------------------
# Prefill chunk graph
# ---------------------------------------------------------------------------

def _prefill_attn(q, k, v, q_pos, lens, use_pallas):
    if use_pallas:
        return attn_k.prefill_attention(q, k, v, q_pos, lens)
    return kref.prefill_attention_ref(q, k, v, q_pos, lens)


def make_prefill_chunk(params, cfg: ModelConfig = MODEL, lay: StateLayout = LAYOUT,
                       use_pallas: bool = True) -> Callable:
    """prefill_chunk(state, tokens[C] i32, slot i32, start i32, nvalid i32).

    Processes ``nvalid`` prompt tokens of one slot at absolute positions
    ``start..start+nvalid-1``. Side effects on the state tensor:

    * that slot's KV gains the chunk's keys/values;
    * ``ptap_sum[:, slot]`` accumulates per-layer hidden-state sums over
      valid tokens and ``pcnt[slot] += nvalid`` (prompt-probe input);
    * ``logits[slot]`` and ``taps[:, slot]`` are set from the chunk's last
      valid token — after the final chunk these are exactly the first
      decode outputs, so TTFT is measured at prefill completion like vLLM.
    """
    c, s = cfg.prefill_chunk, cfg.max_seq
    nt, b, d = cfg.n_taps, cfg.batch_slots, cfg.d_model

    hsd = cfg.n_heads * s * cfg.d_head

    def chunk(state, tokens, slot, start, nvalid):
        x = params["embed"][tokens]                         # [C, D]
        valid = (jnp.arange(c) < nvalid).astype(jnp.float32)  # [C]
        q_pos = start + jnp.arange(c, dtype=jnp.int32)
        total_len = start + nvalid
        last = jnp.maximum(nvalid - 1, 0)
        taps_sums = [jnp.sum(x * valid[:, None], axis=0)]   # per-layer [D]
        taps_last = [x[last]]
        for l in range(cfg.n_layers):
            h = rmsnorm(x, params[f"l{l}.attn_norm"])
            q, k, v = _qkv(h, params, l, cfg)               # [C, H, Dh]
            q = rope(q, q_pos, cfg)
            k = rope(k, q_pos, cfg)
            # Chunk positions are contiguous: one [H, C, Dh] DUS per K/V
            # into the slot's cache (in place when the state is donated;
            # positions past nvalid hold dead values masked by length).
            for which, val in ((0, k), (1, v)):
                base = lay.kv_off + (l * 2 + which) * b * hsd
                slot_base = base + slot * hsd
                kv3 = jax.lax.dynamic_slice(state, (slot_base,), (hsd,)).reshape(
                    cfg.n_heads, s, cfg.d_head)  # slot is dynamic here
                kv3 = jax.lax.dynamic_update_slice(
                    kv3, val.transpose(1, 0, 2), (0, start, 0))
                state = jax.lax.dynamic_update_slice(state, kv3.reshape(-1), (slot_base,))
                if which == 0:
                    kc = kv3
                else:
                    vc = kv3
            out = _prefill_attn(q, kc, vc, q_pos, total_len, use_pallas)
            x = x + out.reshape(c, -1) @ params[f"l{l}.wo"]
            x = x + _ffn(rmsnorm(x, params[f"l{l}.ffn_norm"]), params, l)
            taps_sums.append(jnp.sum(x * valid[:, None], axis=0))
            taps_last.append(x[last])
        logits_last = rmsnorm(x[last], params["final_norm"]) @ params["embed"].T

        # --- merge the slot-local results into the packed regions ---
        logits = state[lay.logits_off:lay.logits_off + lay.logits_len].reshape(b, -1)
        logits = jax.lax.dynamic_update_index_in_dim(logits, logits_last, slot, 0)
        state = jax.lax.dynamic_update_slice(state, logits.reshape(-1), (lay.logits_off,))
        taps = state[lay.taps_off:lay.taps_off + lay.taps_len].reshape(nt, b, d)
        taps = jax.lax.dynamic_update_slice(
            taps, jnp.stack(taps_last)[:, None, :], (0, slot, 0))
        state = jax.lax.dynamic_update_slice(state, taps.reshape(-1), (lay.taps_off,))
        ptap = state[lay.ptap_off:lay.ptap_off + lay.ptap_len].reshape(nt, b, d)
        ptap_slot = jax.lax.dynamic_slice(ptap, (0, slot, 0), (nt, 1, d))
        ptap = jax.lax.dynamic_update_slice(
            ptap, ptap_slot + jnp.stack(taps_sums)[:, None, :], (0, slot, 0))
        state = jax.lax.dynamic_update_slice(state, ptap.reshape(-1), (lay.ptap_off,))
        pcnt = state[lay.pcnt_off:lay.pcnt_off + lay.pcnt_len]
        pcnt = pcnt.at[slot].add(nvalid.astype(jnp.float32))
        state = jax.lax.dynamic_update_slice(state, pcnt, (lay.pcnt_off,))
        return state

    return chunk


# ---------------------------------------------------------------------------
# Readout graph (small host-visible values only)
# ---------------------------------------------------------------------------

def make_readout(cfg: ModelConfig = MODEL, lay: StateLayout = LAYOUT) -> Callable:
    """readout(state) -> (logits[B,V], taps[T,B,D], prompt_taps[T,B,D], argmax[B])."""
    nt, b, d = cfg.n_taps, cfg.batch_slots, cfg.d_model

    def readout(state):
        logits = state[lay.logits_off:lay.logits_off + lay.logits_len].reshape(b, -1)
        taps = state[lay.taps_off:lay.taps_off + lay.taps_len].reshape(nt, b, d)
        ptap = state[lay.ptap_off:lay.ptap_off + lay.ptap_len].reshape(nt, b, d)
        pcnt = state[lay.pcnt_off:lay.pcnt_off + lay.pcnt_len]
        ptap_mean = ptap / jnp.maximum(pcnt[None, :, None], 1.0)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, taps, ptap_mean, nxt

    return readout


def make_slot_reset(cfg: ModelConfig = MODEL, lay: StateLayout = LAYOUT) -> Callable:
    """slot_reset(state, slot) -> state with that slot's prompt-tap
    accumulators cleared (KV needs no clearing — it is length-masked)."""
    nt, b, d = cfg.n_taps, cfg.batch_slots, cfg.d_model

    def reset(state, slot):
        ptap = state[lay.ptap_off:lay.ptap_off + lay.ptap_len].reshape(nt, b, d)
        ptap = jax.lax.dynamic_update_slice(
            ptap, jnp.zeros((nt, 1, d), jnp.float32), (0, slot, 0))
        state = jax.lax.dynamic_update_slice(state, ptap.reshape(-1), (lay.ptap_off,))
        pcnt = state[lay.pcnt_off:lay.pcnt_off + lay.pcnt_len]
        pcnt = pcnt.at[slot].set(0.0)
        return jax.lax.dynamic_update_slice(state, pcnt, (lay.pcnt_off,))

    return reset


def make_predictor(use_pallas: bool = True) -> Callable:
    """predictor(x[N,D], w1, b1, w2, b2) -> probs[N,K] (probe MLP)."""
    if use_pallas:
        return mlp_k.predictor_mlp
    return kref.predictor_mlp_ref


# ---------------------------------------------------------------------------
# Pure-jnp batch oracle (profiling + tests). Independent of the packed
# state machinery above; used to cross-check it.
# ---------------------------------------------------------------------------

def full_forward(params, tokens, cfg: ModelConfig = MODEL):
    """Causal full-sequence forward.

    tokens: [B, T] int32 (padded; padding positions produce garbage the
    caller masks out). Returns (hiddens [B, T, L+1, D], logits [B, T, V]).
    Mathematically identical to running prefill+decode incrementally, which
    is exactly what tests assert.
    """
    bsz, t = tokens.shape
    x = params["embed"][tokens]                       # [B, T, D]
    pos = jnp.arange(t, dtype=jnp.int32)
    causal = pos[None, :] <= pos[:, None]             # [T, T] keys <= queries
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
    hiddens = [x]
    for l in range(cfg.n_layers):
        h = rmsnorm(x, params[f"l{l}.attn_norm"])
        q, k, v = _qkv(h, params, l, cfg)             # [B, T, H, Dh]
        q = rope(q, pos[None, :], cfg)
        k = rope(k, pos[None, :], cfg)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
        scores = jnp.where(causal[None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(bsz, t, -1)
        x = x + out @ params[f"l{l}.wo"]
        x = x + _ffn(rmsnorm(x, params[f"l{l}.ffn_norm"]), params, l)
        hiddens.append(x)
    logits = rmsnorm(x, params["final_norm"]) @ params["embed"].T
    return jnp.stack(hiddens, axis=2), logits


@functools.partial(jax.jit, static_argnames=("n_steps",))
def _generate_scan(params, prompts, plens, n_steps):
    """Greedy continuation of padded prompts via cached incremental decode.

    prompts: [B, P] int32; plens: [B] int32. Returns tokens [B, n_steps]
    (token j = output token j+1; output token 1 comes from the prefill
    logits and is also returned, as out_first).
    """
    cfg = MODEL
    bsz, p = prompts.shape
    s = p + n_steps + 1
    kv = jnp.zeros((cfg.n_layers, 2, bsz, cfg.n_heads, s, cfg.d_head), jnp.float32)

    # Prefill via full forward (exact), then copy K/V into the cache.
    pos = jnp.arange(p, dtype=jnp.int32)
    x = params["embed"][prompts]
    causal = pos[None, :] <= pos[:, None]
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
    # Padding mask: queries may only attend to keys < plen… prompts are
    # *left-packed* so causal masking alone is correct for keys <= query,
    # and garbage beyond plen is never read because the last real token is
    # at plen-1 and decode lens clamp to real positions.
    for l in range(cfg.n_layers):
        h = rmsnorm(x, params[f"l{l}.attn_norm"])
        q, k, v = _qkv(h, params, l, cfg)
        q = rope(q, pos[None, :], cfg)
        k = rope(k, pos[None, :], cfg)
        kv = kv.at[l, 0, :, :, :p].set(k.transpose(0, 2, 1, 3))
        kv = kv.at[l, 1, :, :, :p].set(v.transpose(0, 2, 1, 3))
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
        scores = jnp.where(causal[None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(bsz, p, -1)
        x = x + out @ params[f"l{l}.wo"]
        x = x + _ffn(rmsnorm(x, params[f"l{l}.ffn_norm"]), params, l)
    logits_p = rmsnorm(x, params["final_norm"]) @ params["embed"].T  # [B, P, V]
    last_idx = jnp.maximum(plens - 1, 0)
    first_tok = jnp.argmax(
        jnp.take_along_axis(logits_p, last_idx[:, None, None], 1)[:, 0], -1
    ).astype(jnp.int32)

    def step(carry, t):
        kv, tok, cur_pos = carry
        x = params["embed"][tok]                       # [B, D]
        lens = cur_pos + 1
        oh = (jnp.arange(s)[None, :] == cur_pos[:, None]).astype(jnp.float32)
        ohb = oh[:, None, :, None]
        new_kv = []
        for l in range(cfg.n_layers):
            h = rmsnorm(x, params[f"l{l}.attn_norm"])
            q, k, v = _qkv(h, params, l, cfg)
            q = rope(q, cur_pos, cfg)
            k = rope(k, cur_pos, cfg)
            kc = kv[l, 0] * (1.0 - ohb) + k[:, :, None, :] * ohb
            vc = kv[l, 1] * (1.0 - ohb) + v[:, :, None, :] * ohb
            new_kv.append(jnp.stack([kc, vc]))
            out = kref.decode_attention_ref(q, kc, vc, lens)
            x = x + out.reshape(bsz, -1) @ params[f"l{l}.wo"]
            x = x + _ffn(rmsnorm(x, params[f"l{l}.ffn_norm"]), params, l)
        logits = rmsnorm(x, params["final_norm"]) @ params["embed"].T
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return (jnp.stack(new_kv), nxt, cur_pos + 1), tok

    (_, _, _), toks = jax.lax.scan(
        step, (kv, first_tok, plens), jnp.arange(n_steps))
    return first_tok, toks.T  # [B], [B, n_steps]


def generate_batch(params, prompts, plens, n_steps):
    """Greedy-decode a padded batch; returns full sequences [B, P+n_steps+1]
    where position plens[b]-1+j holds output token j."""
    first, toks = _generate_scan(params, prompts, plens, n_steps)
    bsz, p = prompts.shape
    seqs = jnp.concatenate([prompts, jnp.zeros((bsz, n_steps + 1), jnp.int32)], 1)
    # Output token 1 goes at position plen, token j+1 at plen+j.
    idx = plens[:, None] + jnp.arange(n_steps + 1)[None, :]
    vals = jnp.concatenate([first[:, None], toks], axis=1)
    b_idx = jnp.arange(bsz)[:, None]
    seqs = seqs.at[b_idx, idx].set(vals)
    return seqs
