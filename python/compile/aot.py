"""AOT pipeline: lower every graph to HLO *text* and emit the config /
weights / golden interchange files for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONCE, here; the Rust binary is self-contained afterwards.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import probe as P
from .config import LAYOUT, MODEL, PROBE, config_dict
from .workload import golden_vectors


def to_hlo_text(lowered, return_tuple: bool) -> str:
    """``return_tuple=False`` for single-output graphs (step/prefill):
    the root is then the bare state array, so the Rust runtime can feed
    the output PjRtBuffer of one call directly into the next ``execute_b``
    with zero host traffic (DESIGN.md §1 packed-state design). The
    readout graph uses ``return_tuple=True`` and is decomposed on the
    host (it only carries a few KB)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    # print_large_constants=True: the model weights are baked into the
    # graph as constants; the default printer elides them as '{...}' which
    # the text parser cannot round-trip.
    return comp.as_hlo_text(True)


def lower_to_file(fn, args, path: str, name: str, return_tuple: bool = False,
                  donate_state: bool = False) -> int:
    t0 = time.time()
    # donate_argnums=(0,) marks the packed state as input/output-aliased;
    # XLA then performs the per-step KV writes in place instead of copying
    # the 10.5 MB buffer (EXPERIMENTS.md §Perf L2). The Rust runtime moves
    # the buffer through each call, matching donation semantics.
    jitted = jax.jit(fn, donate_argnums=(0,)) if donate_state else jax.jit(fn)
    text = to_hlo_text(jitted.lower(*args), return_tuple)
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] {name}: {len(text)/1e6:.2f} MB HLO text "
          f"({time.time()-t0:.1f}s) -> {path}", flush=True)
    return len(text)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def emit_model_artifacts(params, outdir: str, use_pallas: bool = True):
    cfg, lay = MODEL, LAYOUT
    b, c = cfg.batch_slots, cfg.prefill_chunk

    step = M.make_decode_step(params, use_pallas=use_pallas)
    lower_to_file(step, (f32(lay.total), i32(b), i32(b), f32(b)),
                  os.path.join(outdir, "model_step.hlo.txt"), "decode_step",
                  donate_state=True)

    chunk = M.make_prefill_chunk(params, use_pallas=use_pallas)
    lower_to_file(chunk, (f32(lay.total), i32(c), i32(), i32(), i32()),
                  os.path.join(outdir, "model_prefill.hlo.txt"), "prefill_chunk",
                  donate_state=True)

    readout = M.make_readout()
    lower_to_file(readout, (f32(lay.total),),
                  os.path.join(outdir, "model_readout.hlo.txt"), "readout",
                  return_tuple=True)

    reset = M.make_slot_reset()
    lower_to_file(reset, (f32(lay.total), i32()),
                  os.path.join(outdir, "model_slot_reset.hlo.txt"), "slot_reset",
                  donate_state=True)

    pred = M.make_predictor(use_pallas=use_pallas)
    d, hd, k = cfg.d_model, PROBE.hidden, 10
    for n in (cfg.batch_slots,) + tuple(PROBE.table1_batches):
        lower_to_file(
            pred, (f32(n, d), f32(d, hd), f32(hd), f32(hd, k), f32(k)),
            os.path.join(outdir, f"predictor_b{n}.hlo.txt"), f"predictor_b{n}")


def emit_golden(params, outdir: str, use_pallas: bool = True):
    """A golden serving trace the Rust runtime integration test replays:
    two slots prefilled (one chunked), three decode steps, small slices of
    every readout recorded."""
    cfg, lay = MODEL, LAYOUT
    step = jax.jit(M.make_decode_step(params, use_pallas=use_pallas))
    chunk = jax.jit(M.make_prefill_chunk(params, use_pallas=use_pallas))
    readout = jax.jit(M.make_readout())

    state = jnp.zeros((lay.total,), jnp.float32)
    prompt0 = [(i * 7) % 248 + 8 for i in range(20)]
    prompt1 = [(i * 13) % 248 + 8 for i in range(9)]

    c = cfg.prefill_chunk
    pad = lambda ts: jnp.asarray((ts + [0] * c)[:c], jnp.int32)
    state = chunk(state, pad(prompt0[:c]), 0, 0, min(c, 20))
    state = chunk(state, pad(prompt0[c:]), 0, c, 20 - c)
    state = chunk(state, pad(prompt1), 1, 0, 9)

    trace = {"prompt0": prompt0, "prompt1": prompt1, "steps": []}
    logits, taps, ptaps, nxt = readout(state)
    pos = np.array([20, 9] + [0] * (cfg.batch_slots - 2), np.int32)
    toks = np.array(nxt)

    def snap(logits, taps, ptaps, nxt):
        return {
            "logits0": np.asarray(logits[0][:8]).tolist(),
            "logits1": np.asarray(logits[1][:8]).tolist(),
            "tap_l4_s0": np.asarray(taps[4, 0, :8]).tolist(),
            "ptap_l0_s0": np.asarray(ptaps[0, 0, :8]).tolist(),
            "argmax": np.asarray(nxt[:2]).tolist(),
        }

    trace["after_prefill"] = snap(logits, taps, ptaps, nxt)
    for _ in range(3):
        active = jnp.asarray([1.0, 1.0] + [0.0] * (cfg.batch_slots - 2))
        state = step(state, jnp.asarray(toks), jnp.asarray(pos), active)
        logits, taps, ptaps, nxt = readout(state)
        trace["steps"].append(snap(logits, taps, ptaps, nxt))
        toks = np.array(nxt)
        pos = pos + 1

    golden = golden_vectors()
    golden["decode_trace"] = trace
    with open(os.path.join(outdir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print(f"[aot] golden.json written", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)  # legacy
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower with the pure-jnp reference path instead of "
                         "the Pallas kernels (perf-pass ablation)")
    ap.add_argument("--quick", action="store_true",
                    help="small probe run (CI/tests)")
    ap.add_argument("--skip-probe", action="store_true")
    args = ap.parse_args()
    outdir = args.outdir if args.out is None else os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)
    use_pallas = not args.no_pallas

    t0 = time.time()
    params = M.init_params()
    print(f"[aot] TrailLM: {M.param_count()} params, "
          f"state {LAYOUT.total * 4 / 1e6:.1f} MB", flush=True)

    with open(os.path.join(outdir, "config.json"), "w") as f:
        json.dump(config_dict(), f, indent=1)

    emit_model_artifacts(params, outdir, use_pallas)
    emit_golden(params, outdir, use_pallas)

    if not args.skip_probe:
        if args.quick:
            P.run(params, outdir, n_requests=48, train_steps=200)
        else:
            P.run(params, outdir)

    # Marker consumed by the Makefile's up-to-date check.
    with open(os.path.join(outdir, ".stamp"), "w") as f:
        f.write(str(time.time()))
    print(f"[aot] done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
