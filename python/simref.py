"""Reference mirror of the Rust `sim` subsystem (``rust/src/sim/``).

A line-faithful transcription of the deterministic co-simulation path:
``MockBackend`` cost accrual, ``KvManager`` accounting,
``ServingEngine::step`` (select_targets / ensure_resident / resolve_oom /
chunked prefill / decode / finish), the ``OraclePredictor`` (exact
refinement), ``TraceWorkload`` generation, the ``SimDriver`` event loop
with cross-replica migration, and the byte-format of
``BenchReport::to_json_string``.

Purpose: cross-language pinning of ``benchmarks/BENCH_seed.json``. The
checked-in baseline is generated here and must match what
``trail-serve sim`` (the Rust binary) produces bit-for-bit — every
arithmetic operation below mirrors the Rust order of operations, all
draws come from the shared SplitMix64 mirror, and floats are IEEE
doubles in both languages. (The only platform sensitivity is libm
``exp``/``log`` in the workload generator; regenerate with
``make bench-sim-refresh`` if a libm ever disagrees.)

Usage:
    cd python && python3 simref.py sweep --out ../benchmarks/BENCH_seed.json
"""

import math
import sys
from dataclasses import replace

from compile.config import BINS, MODEL, WORKLOAD
from compile.prng import SplitMix64, normal_from_uniform

# ---------------------------------------------------------------------------
# Engine constants (rust/src/coordinator/{engine,backend}.rs)
# ---------------------------------------------------------------------------

MAX_SEQ = MODEL.max_seq                # 320
CHUNK = MODEL.prefill_chunk            # 16
PREFILL_CHUNKS_PER_ITER = 2
EVICT_MARGIN = BINS.width / 2.0        # 12.8

# CostModel::default()
COST_DECODE_STEP = 2.0e-3
COST_DECODE_PER_SLOT = 0.25e-3
COST_PREFILL_CHUNK = 2.5e-3
COST_READOUT = 0.3e-3

WAITING, PREFILLING, RUNNING, PREEMPTED, DISCARDED, FINISHED = range(6)


class Req:
    __slots__ = (
        "rid", "plen", "n_out", "tenant", "phase", "slot", "prefilled",
        "generated", "kv_written", "initial_pred", "pred_remaining",
        "arrival", "first_token_at", "finished_at", "n_preemptions",
        "n_discards", "n_migrations",
    )

    def __init__(self, rid, plen, n_out, tenant, arrival):
        self.rid = rid
        self.plen = plen
        self.n_out = n_out
        self.tenant = tenant
        self.phase = WAITING
        self.slot = None
        self.prefilled = 0
        self.generated = 0
        self.kv_written = 0
        # OraclePredictor::init_request (noise 0)
        self.initial_pred = float(n_out)
        self.pred_remaining = float(n_out)
        self.arrival = arrival
        self.first_token_at = None
        self.finished_at = None
        self.n_preemptions = 0
        self.n_discards = 0
        self.n_migrations = 0

    def prefill_target(self):
        return self.plen + max(self.generated - 1, 0)

    def prefill_done(self):
        return self.kv_written >= self.prefill_target()

    def preemptable(self, c):
        if self.generated == 0:
            return True
        return self.generated < math.floor(c * self.initial_pred)

    def done(self):
        return self.generated >= self.n_out


# Policies: ("fcfs",), ("sjf",), ("trail", c). Rank mirrors
# rust/src/coordinator/policy.rs — tuple (0 locked / 1 unlocked, key,
# tie, rid); lexicographic tuple order == Rank::cmp.
def rank(policy, r):
    tie = r.arrival
    if policy[0] == "fcfs":
        locked = r.phase in (RUNNING, PREFILLING, PREEMPTED)
        key = r.arrival
    elif policy[0] == "sjf":
        locked = r.phase != WAITING
        key = r.pred_remaining
    else:  # trail
        locked = (not r.preemptable(policy[1])) and r.phase != WAITING
        key = r.pred_remaining
    return (0 if locked else 1, key, tie, r.rid)


def policy_preemptive(policy):
    return policy[0] == "trail"


def policy_c(policy):
    return policy[1] if policy[0] == "trail" else 1.0


def policy_name(policy):
    if policy[0] == "fcfs":
        return "fcfs"
    if policy[0] == "sjf":
        return "sjf-prompt"
    c = policy[1]
    return "trail-c" + (str(int(c)) if c == int(c) else repr(c))


class Kv:
    """rust/src/coordinator/kv.rs"""

    def __init__(self, n_slots, pool_tokens):
        self.n_slots = n_slots
        self.pool_tokens = pool_tokens
        self.slots = [None] * n_slots
        self.charged = [0] * n_slots

    def used_tokens(self):
        return sum(self.charged)

    def free_slot_available(self):
        return any(s is None for s in self.slots)

    def alloc(self, rid):
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = rid
                self.charged[i] = 0
                return i
        return None

    def charge(self, slot, rid, tokens):
        assert self.slots[slot] == rid, "slot not owned"
        assert tokens <= MAX_SEQ
        self.charged[slot] = tokens

    def free(self, slot, rid):
        assert self.slots[slot] == rid, "slot not owned"
        self.slots[slot] = None
        self.charged[slot] = 0

    def fits(self, extra):
        return self.used_tokens() + extra <= self.pool_tokens


class Engine:
    """Virtual-clock ServingEngine<MockBackend> with the oracle predictor
    (multiplicative log-normal noise on the initial estimate, exact
    refinement per token — OraclePredictor{noise, refine_exact, seed})."""

    def __init__(self, policy, slots, pool_tokens, noise=0.4, pred_seed=7,
                 max_iterations=2_000_000):
        self.policy = policy
        self.slots = slots
        self.kv = Kv(slots, pool_tokens)
        self.noise = noise
        self.pred_rng = SplitMix64(pred_seed)
        self.now = 0.0
        self.reqs = []
        self.finished_rids = []
        self.pending_cost = 0.0
        self.n_iter = 0
        self.max_iterations = max_iterations
        # metrics
        self.lat = []
        self.ttft = []
        self.n_finished = 0
        self.m_preemptions = 0
        self.m_discards = 0
        self.m_migrations = 0
        self.peak_mem = 0

    # --- clock ---
    def sync_clock(self, at):
        if at > self.now:
            self.now = at

    # --- status ---
    def any_schedulable(self):
        return any(r.phase != FINISHED for r in self.reqs)

    def live(self):
        return sum(1 for r in self.reqs if r.phase != FINISHED)

    def resident(self):
        return sum(1 for r in self.reqs if r.phase != FINISHED and r.slot is not None)

    def pred_sum(self):
        s = 0.0
        for r in self.reqs:
            if r.phase != FINISHED:
                s += max(r.pred_remaining, 0.0)
        return s

    def admit(self, req):
        # OraclePredictor::init_request (one normal draw per admission,
        # in admission order, from this engine's predictor stream).
        if self.noise != 0.0:
            z = normal_from_uniform(self.pred_rng.next_f64())
            est = max(float(req.n_out) * math.exp(self.noise * z), 1.0)
            req.initial_pred = est
            req.pred_remaining = est
        self.reqs.append(req)

    # --- migration (rust ServingEngine::take_migratable) ---
    def take_migratable(self):
        pick = None  # (resident, rank, idx)
        for i, r in enumerate(self.reqs):
            if r.phase == FINISHED:
                continue
            rk = rank(self.policy, r)
            if rk[0] == 0:  # locked
                continue
            res = r.slot is not None
            if pick is None:
                better = True
            else:
                pres, prank, _ = pick
                if res != pres:
                    better = not res
                else:
                    better = rk > prank
            if better:
                pick = (res, rk, i)
        if pick is None:
            return None
        idx = pick[2]
        # Vec::swap_remove
        if idx == len(self.reqs) - 1:
            r = self.reqs.pop()
        else:
            r = self.reqs[idx]
            self.reqs[idx] = self.reqs.pop()
        if r.slot is not None:
            self.kv.free(r.slot, r.rid)
            r.slot = None
        r.prefilled = 0
        r.kv_written = 0
        r.phase = WAITING if r.generated == 0 else DISCARDED
        r.n_migrations += 1
        return r

    def admit_migrated(self, r):
        self.reqs.append(r)

    # --- step (rust step/step_inner) ---
    def step(self):
        if not self.any_schedulable():
            return False, []
        if self.max_iterations > 0 and self.n_iter >= self.max_iterations:
            raise RuntimeError("max_iterations exceeded — scheduler stall?")
        reqs = self.reqs
        self.resolve_oom(reqs)
        target = self.select_targets(reqs)

        # ---- prefill budget ----
        prefill_done_now = []
        budget = PREFILL_CHUNKS_PER_ITER
        chunks_issued = 0
        for idx in target:
            if budget == 0:
                break
            r = reqs[idx]
            if r.prefill_done():
                continue
            while budget > 0 and not r.prefill_done():
                tokens_len = r.prefill_target()
                start = r.prefilled
                nvalid = min(tokens_len - start, CHUNK)
                if not self.kv.fits(nvalid):
                    break
                self.pending_cost += COST_PREFILL_CHUNK
                r.prefilled += nvalid
                r.kv_written = r.prefilled
                self.kv.charge(r.slot, r.rid, r.kv_written)
                budget -= 1
                chunks_issued += 1
            self.kv.charge(r.slot, r.rid, r.kv_written)
            if r.prefill_done():
                prefill_done_now.append(idx)

        # ---- decode ----
        decoding = []
        for idx in target:
            r = reqs[idx]
            if (
                r.phase == RUNNING
                and r.prefill_done()
                and r.generated >= 1
                and idx not in prefill_done_now
            ):
                decoding.append(idx)
        if decoding:
            self.pending_cost += COST_DECODE_STEP + COST_DECODE_PER_SLOT * len(decoding)

        # ---- readout + clock ----
        stepped = bool(decoding) or bool(prefill_done_now)
        if stepped:
            self.pending_cost += COST_READOUT
        cost = self.pending_cost
        self.pending_cost = 0.0
        self.now += cost
        now = self.now

        if stepped:
            for idx in prefill_done_now:
                r = reqs[idx]
                if r.generated == 0:
                    r.generated = 1
                    r.first_token_at = now
                self.kv.charge(r.slot, r.rid, r.kv_written)
                self.finish_if_done(r, now)
            for idx in decoding:
                r = reqs[idx]
                r.kv_written = max(r.kv_written, r.plen + r.generated - 1 + 1)
                r.generated += 1
                r.pred_remaining = max(float(r.n_out - r.generated), 0.0)
                self.kv.charge(r.slot, r.rid, r.kv_written)
                self.finish_if_done(r, now)

        used = self.kv.used_tokens()
        if used > self.peak_mem:
            self.peak_mem = used
        self.n_iter += 1

        finished = []
        for rid in self.finished_rids:
            r = next(r for r in reqs if r.rid == rid)
            finished.append((rid, r.finished_at - r.arrival, r.first_token_at - r.arrival, r.generated))
        self.finished_rids = []
        self.reqs = [r for r in reqs if r.phase != FINISHED]
        worked = stepped or chunks_issued > 0
        return worked, finished

    def finish_if_done(self, r, now):
        if r.done() and r.phase != FINISHED:
            r.finished_at = now
            r.phase = FINISHED
            if r.slot is not None:
                self.kv.free(r.slot, r.rid)
                r.slot = None
            # Metrics::observe_finish
            self.n_finished += 1
            self.lat.append(r.finished_at - r.arrival)
            self.ttft.append(r.first_token_at - r.arrival)
            self.m_preemptions += r.n_preemptions
            self.m_discards += r.n_discards
            self.m_migrations += r.n_migrations
            self.finished_rids.append(r.rid)

    def resolve_oom(self, reqs):
        c = policy_c(self.policy)
        while not self.kv.fits(0):
            cands = [
                (i, r)
                for i, r in enumerate(reqs)
                if r.slot is not None and r.phase != FINISHED and r.preemptable(c)
            ]
            if not cands:
                cands = [
                    (i, r)
                    for i, r in enumerate(reqs)
                    if r.slot is not None and r.phase != FINISHED
                ]
            if not cands:
                break
            _, r = max(cands, key=lambda t: rank(self.policy, t[1]))
            self.kv.free(r.slot, r.rid)
            r.slot = None
            r.phase = DISCARDED
            r.prefilled = 0
            r.kv_written = 0
            r.n_discards += 1

    def select_targets(self, reqs):
        order = [i for i in range(len(reqs)) if reqs[i].phase != FINISHED]
        order.sort(key=lambda i: rank(self.policy, reqs[i]))
        target = []
        chosen = [False] * len(reqs)
        for idx in order:
            if len(target) >= self.slots:
                break
            if self.ensure_resident(reqs, idx, chosen):
                chosen[idx] = True
                target.append(idx)
        for i, r in enumerate(reqs):
            if not chosen[i] and r.phase == RUNNING:
                r.phase = PREEMPTED
                r.n_preemptions += 1
            elif chosen[i] and r.phase in (PREEMPTED, WAITING, DISCARDED):
                r.phase = RUNNING if r.prefill_done() else PREFILLING
            elif chosen[i] and r.phase == PREFILLING and r.prefill_done():
                r.phase = RUNNING
        return target

    def ensure_resident(self, reqs, idx, chosen):
        if reqs[idx].slot is not None:
            return True
        c = policy_c(self.policy)
        need = min(reqs[idx].prefill_target(), MAX_SEQ)
        while True:
            have_slot = self.kv.free_slot_available()
            have_mem = self.kv.fits(min(need, CHUNK * 2))
            if have_slot and have_mem:
                break
            victims = [
                (i, r)
                for i, r in enumerate(reqs)
                if not chosen[i]
                and r.slot is not None
                and r.phase != FINISHED
                and policy_preemptive(self.policy)
                and r.preemptable(c)
            ]
            if not victims:
                return False
            _, vreq = max(victims, key=lambda t: rank(self.policy, t[1]))
            vr = rank(self.policy, vreq)
            cr = rank(self.policy, reqs[idx])
            if not vr > cr:
                return False
            if vr[0] == 1 and cr[0] == 1 and vr[1] - cr[1] < EVICT_MARGIN:
                return False
            self.kv.free(vreq.slot, vreq.rid)
            vreq.slot = None
            vreq.phase = DISCARDED
            vreq.prefilled = 0
            vreq.kv_written = 0
            vreq.n_discards += 1
        slot = self.kv.alloc(reqs[idx].rid)
        assert slot is not None
        reqs[idx].slot = slot
        reqs[idx].prefilled = 0
        reqs[idx].kv_written = 0
        return True


# ---------------------------------------------------------------------------
# Trace workload (rust/src/workload/trace.rs)
# ---------------------------------------------------------------------------

def tenant_arrivals(rate, phases, n, rng):
    out = []
    t = 0.0
    phase_idx = 0
    if not phases:
        cur_rate, phase_left = rate, float("inf")
    else:
        cur_rate, phase_left = rate * phases[0][0], phases[0][1]
    while len(out) < n:
        e = -math.log(1.0 - rng.next_f64())
        while True:
            if cur_rate > 0.0 and e <= cur_rate * phase_left:
                dt = e / cur_rate
                t += dt
                phase_left -= dt
                out.append(t)
                break
            e -= cur_rate * phase_left
            t += phase_left
            phase_idx = (phase_idx + 1) % len(phases)
            phase_left = phases[phase_idx][1]
            cur_rate = rate * phases[phase_idx][0]
    return out


class TenantGen:
    """WorkloadGen mirror, reduced to (plen, n_out): the oracle co-sim
    never reads token values, and the per-request child stream is split
    off the master, so skipping token draws does not perturb anything."""

    def __init__(self, seed, mu_shift):
        self.master = SplitMix64(seed)
        self.w = replace(WORKLOAD, lognormal_mu=WORKLOAD.lognormal_mu + mu_shift)

    def next_request(self):
        rng = self.master.split()
        # sample_output_len
        z = normal_from_uniform(rng.next_f64())
        x = math.exp(self.w.lognormal_mu + self.w.lognormal_sigma * z)
        n = int(x + 0.5)
        n_out = min(max(n, self.w.min_output), self.w.max_output)
        # observed_class draws one uniform (value unused here)
        rng.next_f64()
        plen = rng.next_range(self.w.min_prompt, self.w.max_prompt)
        return plen, n_out


def generate_trace(tenants, n, seed):
    """tenants: list of (rate, mu_shift, phases) — phases: [(mult, dur)]."""
    master = SplitMix64(seed)
    streams = []
    for (rate, mu_shift, phases) in tenants:
        spec_seed = master.next_u64()
        arr_rng = SplitMix64(master.next_u64())
        times = tenant_arrivals(rate, phases, n, arr_rng)
        streams.append([times, TenantGen(spec_seed, mu_shift), 0])
    out = []
    while len(out) < n:
        best = None
        for ti, (times, _, pos) in enumerate(streams):
            at = times[pos]
            if best is None or at < best[0]:
                best = (at, ti)
        at, ti = best
        stream = streams[ti]
        stream[2] += 1
        plen, n_out = stream[1].next_request()
        out.append((at, ti, len(out), plen, n_out))  # (at, tenant, rid, plen, n_out)
    return out


# ---------------------------------------------------------------------------
# Driver (rust/src/sim/driver.rs)
# ---------------------------------------------------------------------------

def pick_replica(dispatch, engines, rr):
    if dispatch == "rr":
        return rr % len(engines)
    if dispatch == "jsq":
        return min(range(len(engines)), key=lambda i: (engines[i].live(), i))
    # least-work (unseen is always 0 on the co-sim path)
    return min(
        range(len(engines)),
        key=lambda i: (engines[i].pred_sum(), engines[i].live(), i),
    )


def run_sim(trace, policy, replicas, dispatch, migration, slots, pool_tokens, noise=0.4):
    engines = [Engine(policy, slots, pool_tokens, noise=noise) for _ in range(replicas)]
    n_total = len(trace)
    nxt = 0
    rr = 0
    n_migrations = 0
    lat = []
    ttft = []
    finished = 0
    stalled = [False] * replicas

    def rebalance(now):
        nonlocal n_migrations
        moved = False
        while True:
            idle = next((j for j in range(replicas) if not engines[j].any_schedulable()), None)
            if idle is None:
                break
            donors = []  # (waiting, k)
            for k in range(replicas):
                if k == idle:
                    continue
                waiting = engines[k].live() - engines[k].resident()
                if waiting <= 0 or (engines[k].resident() == 0 and waiting < 2):
                    continue
                donors.append((waiting, k))
            donors.sort(key=lambda t: (-t[0], t[1]))
            migrated = False
            for _, k in donors:
                req = engines[k].take_migratable()
                if req is None:
                    continue
                engines[idle].sync_clock(now)
                engines[idle].admit_migrated(req)
                stalled[idle] = False
                stalled[k] = False
                n_migrations += 1
                moved = True
                migrated = True
                break
            if not migrated:
                break
        return moved

    while True:
        active = None
        for i, e in enumerate(engines):
            if stalled[i] or not e.any_schedulable():
                continue
            now = e.now
            if active is None or now < active[0]:
                active = (now, i)

        if nxt < n_total and (active is None or trace[nxt][0] <= active[0]):
            at, tenant, rid, plen, n_out = trace[nxt]
            nxt += 1
            idx = pick_replica(dispatch, engines, rr)
            rr += 1
            engines[idx].sync_clock(at)
            engines[idx].admit(Req(rid, plen, n_out, tenant, at))
            stalled[idx] = False
            continue

        if active is None:
            if any(e.any_schedulable() for e in engines):
                now = max(0.0, *[e.now for e in engines])
                if migration and rebalance(now):
                    continue
                raise RuntimeError("co-sim stalled")
            break

        now, i = active
        if migration and rebalance(now):
            continue
        worked, fin = engines[i].step()
        if not worked:
            stalled[i] = True
        for (_, l, t, _) in fin:
            finished += 1
            lat.append(l)
            ttft.append(t)

    assert finished == n_total, f"lost requests: {finished}/{n_total}"
    makespan = max(e.now for e in engines)
    return {
        "n": finished,
        "lat": lat,
        "ttft": ttft,
        "preemptions": sum(e.m_preemptions for e in engines),
        "discards": sum(e.m_discards for e in engines),
        "migrations": n_migrations,
        "kv_peak": max(e.peak_mem for e in engines),
        "per_replica": [e.n_finished for e in engines],
        "makespan": makespan,
        "iters": sum(e.n_iter for e in engines),
    }


# ---------------------------------------------------------------------------
# Scenarios (rust/src/sim/scenario.rs builtins — keep in sync!)
# ---------------------------------------------------------------------------

def builtin_scenarios():
    # name -> (tenants, n, seed, dispatch, slots, pool_frac, noise)
    # Keep in sync with rust/src/sim/scenario.rs `builtin`.
    return {
        "steady": ([(170.0, 0.0, [])], 500, 9001, "jsq", 128, 0.55, 0.4),
        "bursty": ([(45.0, 0.0, [(4.0, 2.5), (0.2, 5.5)])], 500, 9001, "jsq", 128, 0.55, 0.4),
        "multi-tenant": (
            [
                (90.0, -0.3, []),
                (20.0, 0.9, []),
                (40.0, 0.0, [(2.0, 1.0), (0.5, 3.0)]),
            ],
            500, 9001, "jsq", 128, 0.55, 0.4,
        ),
        "skewed": (
            [
                (14.0, 1.0, [(4.0, 1.5), (0.1, 4.5)]),
                (26.0, -0.5, []),
            ],
            240, 9001, "rr", 16, 0.35, 0.8,
        ),
    }


# ---------------------------------------------------------------------------
# Report serialisation (rust/src/sim/report.rs — byte-format mirror)
# ---------------------------------------------------------------------------

SCHEMA = "trail.simlab.bench/v1"


def jnum(x):
    x = float(x)
    assert math.isfinite(x)
    if x == math.trunc(x) and abs(x) < 1e15:
        return str(int(x))
    r = repr(x)
    assert "e" not in r and "E" not in r, f"exponent formatting diverges from Rust: {r}"
    return r


def mean(xs):
    acc = 0.0
    for x in xs:
        acc += x
    return acc / len(xs)


def percentile(xs, p):
    ys = sorted(xs)
    r = p / 100.0 * (len(ys) - 1)
    lo = math.floor(r)
    hi = math.ceil(r)
    if lo == hi:
        return ys[lo]
    w = r - lo
    return ys[lo] * (1.0 - w) + ys[hi] * w


def row_json(row):
    parts = []
    for k in sorted(row.keys()):
        v = row[k]
        if isinstance(v, str):
            sv = '"' + v + '"'
        elif isinstance(v, bool):
            sv = "true" if v else "false"
        elif isinstance(v, list):
            sv = "[" + ",".join(jnum(x) for x in v) + "]"
        else:
            sv = jnum(v)
        parts.append('"' + k + '":' + sv)
    return "{" + ",".join(parts) + "}"


def report_json(rows):
    s = "{\n"
    s += '"schema":"' + SCHEMA + '",\n'
    s += '"rows":[\n'
    for i, row in enumerate(rows):
        s += row_json(row)
        if i + 1 < len(rows):
            s += ","
        s += "\n"
    s += "]\n}\n"
    return s


def sweep_rows(scenario_names, policies, replica_counts, migration):
    rows = []
    scs = builtin_scenarios()
    for name in scenario_names:
        tenants, n, seed, dispatch, slots, pool_frac, noise = scs[name]
        trace = generate_trace(tenants, n, seed)
        pool_tokens = int((slots * MAX_SEQ) * pool_frac)
        for replicas in replica_counts:
            for policy in policies:
                out = run_sim(trace, policy, replicas, dispatch, migration, slots, pool_tokens, noise)
                rows.append({
                    "scenario": name,
                    "policy": policy_name(policy),
                    "dispatch": {"rr": "round-robin", "jsq": "jsq", "lpw": "least-work"}[dispatch],
                    "replicas": replicas,
                    "migration": migration,
                    "n": out["n"],
                    # u64s travel as strings (golden_fixture.json convention)
                    "seed": str(seed),
                    "mean_latency_s": mean(out["lat"]),
                    "p50_latency_s": percentile(out["lat"], 50.0),
                    "p99_latency_s": percentile(out["lat"], 99.0),
                    "mean_ttft_s": mean(out["ttft"]),
                    "p50_ttft_s": percentile(out["ttft"], 50.0),
                    "p99_ttft_s": percentile(out["ttft"], 99.0),
                    "throughput_req_s": out["n"] / out["makespan"] if out["makespan"] > 0 else 0.0,
                    "makespan_s": out["makespan"],
                    "preemptions": out["preemptions"],
                    "discards": out["discards"],
                    "migrations": out["migrations"],
                    "kv_peak_tokens": out["kv_peak"],
                    "n_iterations": out["iters"],
                    "per_replica_finished": out["per_replica"],
                })
    return rows


DEFAULT_POLICIES = [("fcfs",), ("trail", 1.0), ("trail", 0.8)]


def main(argv):
    if not argv or argv[0] != "sweep":
        print(__doc__)
        return 2
    out_path = None
    if "--out" in argv:
        out_path = argv[argv.index("--out") + 1]
    rows = sweep_rows(
        ["steady", "bursty", "multi-tenant", "skewed"],
        DEFAULT_POLICIES,
        [2, 4],
        migration=True,
    )
    text = report_json(rows)
    for row in rows:
        print(
            f"{row['scenario']:>13} {row['policy']:>10} x{row['replicas']} "
            f"mean={row['mean_latency_s']:.3f}s p99={row['p99_latency_s']:.3f}s "
            f"ttft={row['mean_ttft_s']:.3f}s preempt={row['preemptions']} "
            f"discard={row['discards']} migrate={row['migrations']}"
        )
    if out_path:
        with open(out_path, "w") as f:
            f.write(text)
        print(f"wrote {out_path} ({len(rows)} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
