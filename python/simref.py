"""Reference mirror of the Rust `sim` subsystem (``rust/src/sim/``).

A line-faithful transcription of the deterministic co-simulation path:
``MockBackend`` cost accrual, ``KvManager`` accounting,
``ServingEngine::step`` (select_targets / ensure_resident / resolve_oom /
chunked prefill / decode / finish) under BOTH selectors — the reference
full-sort path and the incremental ``RankIndex`` (lazy bucket queue +
pairing-heap fallback, ``rust/src/coordinator/rank_index.rs``) with its
exact selector-op accounting — the ``OraclePredictor`` (exact
refinement), ``TraceWorkload`` generation, the ``SimDriver`` event loop
with cross-replica migration, per-tenant latency breakdowns, and the
byte-formats of ``BenchReport::to_json_string`` (bench + sched schemas).

Purpose: cross-language pinning of ``benchmarks/BENCH_seed.json`` and
``benchmarks/BENCH_sched.json``. The checked-in baselines are generated
here and must match what ``trail-serve sim`` / ``trail-serve sched``
(the Rust binary) produce bit-for-bit — every arithmetic operation
below mirrors the Rust order of operations, all draws come from the
shared SplitMix64 mirror, and floats are IEEE doubles in both
languages. Both selectors must reproduce the seed baseline
byte-for-byte (``--selector reference|indexed``) — that equivalence is
how the rank-index rewrite was validated. (The only platform
sensitivity is libm ``exp``/``log`` in the workload generator;
regenerate with ``make bench-sim-refresh`` / ``bench-sched-refresh``
if a libm ever disagrees.)

The fairness layer (docs/fairness.md) is mirrored too: the starvation
guard (quantized aging levels folded into the rank key, maintained
incrementally), per-tenant deficit shares (two-pass share-capped
selection in BOTH selectors), the per-request wait-episode tracking
behind ``max_starve_age_s``, and the ``trail.simlab.fair/v1`` report
(per-tenant slowdowns, Jain's index). With neutral knobs every rank,
schedule, and op counter is bit-identical to the fairness-free engine,
which is how BENCH_seed/BENCH_sched stay byte-frozen.

The predictor arena (docs/predictors.md) is mirrored too: every engine
owns a pluggable predictor (``rust/src/predictor/arena.rs``) — the
frozen oracle default (byte-identical to the pre-arena inline path, so
every legacy baseline stays frozen), the noisy observed-class probe,
the deterministic bucket classifier, the rank-only ordinal scorer, and
the online-refresh variant that re-fits per-bucket posteriors from
completions mid-run. Predictor quality (Kendall-τ-b, pairwise
inversion rate, MAE over (initial prediction, truth) pairs in finish
order) and the per-tenant drift knob (a salted side stream that flips
the true output-length distribution mid-trace while the prompt-time
observed class keeps describing the stale truth) pin
``benchmarks/BENCH_pred.json`` (``trail.simlab.pred/v1``)
cross-language exactly like the other grids.

The prefix-sharing KV cache (docs/prefix_cache.md) is mirrored at the
token level: the refcounted block trie with its running ``savings``
counter (shared blocks charged once), attach-on-alloc with the
one-chunk-short cap, prefix-aware admission need, the
``victim_rank`` sharing bonus in every victim scan (OOM + preemption,
both selectors), cache-affinity dispatch with exact per-replica trie
queries, and the agentic/RAG template trace generators — so
``benchmarks/BENCH_prefix.json`` (``trail.simlab.prefix/v1``) is pinned
cross-language exactly like the other grids. With the prefix cache off
(every pre-existing scenario) all of it is inert and the frozen
baselines stay byte-identical.

The flight recorder (docs/observability.md) is mirrored too: the
request-lifecycle + scheduler-decision trace (every emission site at
the same virtual timestamp with the same per-replica sequence numbers,
rendered line-identical to ``rust/src/obs/trace.rs``), the
deterministic phase counters with their cost-model virtual totals, the
FNV-1a trace fingerprint, and the ``trail.simlab.obs/v1`` report
(``benchmarks/BENCH_obs.json``). With obs off (the default everywhere)
every emission helper is a no-op and all frozen baselines stay
byte-identical — that freeze is what ``make bench-freeze-mirror``
regenerates and checks.

The fleet-dynamics layer (docs/fleet.md) is mirrored too: the seeded
crash/recovery event stream interleaved with arrivals and steps on the
shared virtual timeline (``SimDriver::run_fleet``), graceful drain for
scale-down, the queue-depth autoscaler with its boot delay,
per-replica hardware-generation cost multipliers
(``CostModel::scaled``), ``stale_s``-epoch dispatch snapshots, and
SLO-class admission control (batch shed/degrade). Everything is a pure
function of the fleet config — crash times precomputed from one
SplitMix64 stream — so the ``trail.simlab.fleet/v1`` chaos grid
(``benchmarks/BENCH_fleet.json``) is run-twice byte-identical, and the
inert default config serves any trace byte-identically to the plain
serial loop, which is what keeps the eight pre-fleet baselines frozen.

The scale grid (docs/simlab.md) is mirrored too: the
``trail.simlab.scale/v1`` report (``benchmarks/BENCH_scale.json``) —
scale scenarios × worker counts at 8 replicas, migration off. The Rust
parallel driver is byte-identical to its serial event loop (that is the
whole contract), and this mirror *is* that serial loop, so one serial
run per scenario regenerates every worker row; only the ``workers``
field varies across them.

Usage:
    cd python && python3 simref.py sweep --out ../benchmarks/BENCH_seed.json
    cd python && python3 simref.py sweep --selector reference --out /tmp/x.json
    cd python && python3 simref.py sched --out ../benchmarks/BENCH_sched.json
    cd python && python3 simref.py fair --out ../benchmarks/BENCH_fair.json
    cd python && python3 simref.py prefix --out ../benchmarks/BENCH_prefix.json
    cd python && python3 simref.py pred --out ../benchmarks/BENCH_pred.json
    cd python && python3 simref.py obs --out ../benchmarks/BENCH_obs.json \
        --trace-jsonl /tmp/trace.jsonl --timings-json /tmp/timings.json
    cd python && python3 simref.py scale --out ../benchmarks/BENCH_scale.json
    cd python && python3 simref.py fleet --out ../benchmarks/BENCH_fleet.json
"""

import math
import sys
import time
from dataclasses import replace

from compile.config import BINS, MODEL, WORKLOAD
from compile.prng import SplitMix64, normal_from_uniform

# ---------------------------------------------------------------------------
# Engine constants (rust/src/coordinator/{engine,backend}.rs)
# ---------------------------------------------------------------------------

MAX_SEQ = MODEL.max_seq                # 320
CHUNK = MODEL.prefill_chunk            # 16
PREFILL_CHUNKS_PER_ITER = 2
EVICT_MARGIN = BINS.width / 2.0        # 12.8

# CostModel::default()
COST_DECODE_STEP = 2.0e-3
COST_DECODE_PER_SLOT = 0.25e-3
COST_PREFILL_CHUNK = 2.5e-3
COST_READOUT = 0.3e-3

WAITING, PREFILLING, RUNNING, PREEMPTED, DISCARDED, FINISHED = range(6)

# Prefix cache (rust/src/coordinator/kv.rs + engine.rs,
# docs/prefix_cache.md): sharing granularity, the per-shared-token rank
# bonus that makes cheap discards sort toward the victim end, and the
# template-stream salt of the prefix trace generator
# (rust/src/workload/gen.rs).
PREFIX_BLOCK = 16
PREFIX_VICTIM_BONUS_PER_TOKEN = 0.25
PREFIX_TEMPLATE_SALT = 0x9E3779B97F4A7C15

# Cache-affinity dispatch (rust/src/coordinator/dispatch.rs).
AFFINITY_MIN_MATCH = PREFIX_BLOCK
AFFINITY_QUEUE_IMBALANCE = 4

# Predictor arena (rust/src/predictor/arena.rs, docs/predictors.md):
# the salt deriving each drifting tenant's side stream from its spec
# seed, and the EMA weight of the online-refresh posterior.
DRIFT_SALT = 0xD1F75A17ED570A7E
ONLINE_ALPHA = 0.25


def f64_round(x):
    """Rust ``f64::round`` — half away from zero. Python's ``round()``
    is banker's rounding and ``floor(x + 0.5)`` misrounds the double
    just below 0.5, so the jitter quantisation needs this exact form."""
    t = math.trunc(x)
    d = x - t
    if d >= 0.5:
        return t + 1
    if d <= -0.5:
        return t - 1
    return t


class Req:
    __slots__ = (
        "rid", "plen", "n_out", "tenant", "phase", "slot", "prefilled",
        "generated", "kv_written", "initial_pred", "pred_remaining",
        "arrival", "first_token_at", "finished_at", "wait_started",
        "starve_level", "n_preemptions", "n_discards", "n_migrations",
        "prompt", "observed",
    )

    def __init__(self, rid, plen, n_out, tenant, arrival, prompt=None,
                 observed=0):
        self.rid = rid
        self.plen = plen
        self.n_out = n_out
        # Prompt token ids — only prefix traces carry them (the engine
        # reads token values only through the prefix trie).
        self.prompt = prompt
        # Noisy prompt-time length class (RequestSpec::observed_class) —
        # the only feature the arena predictors are allowed to read.
        self.observed = observed
        self.tenant = tenant
        self.phase = WAITING
        self.slot = None
        self.prefilled = 0
        self.generated = 0
        self.kv_written = 0
        # OraclePredictor::init_request (noise 0)
        self.initial_pred = float(n_out)
        self.pred_remaining = float(n_out)
        self.arrival = arrival
        self.first_token_at = None
        self.finished_at = None
        # Fairness (rust/src/coordinator/request.rs): current wait
        # episode start + quantized starvation-guard aging level.
        self.wait_started = arrival
        self.starve_level = 0
        self.n_preemptions = 0
        self.n_discards = 0
        self.n_migrations = 0

    def prefill_target(self):
        return self.plen + max(self.generated - 1, 0)

    def prefill_done(self):
        return self.kv_written >= self.prefill_target()

    def preemptable(self, c):
        if self.generated == 0:
            return True
        return self.generated < math.floor(c * self.initial_pred)

    def done(self):
        return self.generated >= self.n_out


# Policies: ("fcfs",), ("sjf",), ("trail", c). Rank mirrors
# rust/src/coordinator/policy.rs — tuple (0 locked / 1 unlocked, key,
# tie, rid); lexicographic tuple order == Rank::cmp.
def rank(policy, r):
    tie = r.arrival
    if policy[0] == "fcfs":
        locked = r.phase in (RUNNING, PREFILLING, PREEMPTED)
        key = r.arrival
    elif policy[0] == "sjf":
        locked = r.phase != WAITING
        key = r.pred_remaining
    else:  # trail
        locked = (not r.preemptable(policy[1])) and r.phase != WAITING
        key = r.pred_remaining
    return (0 if locked else 1, key, tie, r.rid)


# ---------------------------------------------------------------------------
# Predictor arena (rust/src/predictor/arena.rs)
# ---------------------------------------------------------------------------
#
# Every engine owns one predictor instance (all replicas seeded alike,
# exactly as PredictorSpec::build does in Rust). The oracle is the
# frozen default — byte-identical to the pre-arena inline path — while
# the arena lineup (probe / bucket / rank / online) reads only the
# request's noisy observed class, the stale prompt-time feature that
# mid-trace drift invalidates.


class OraclePred:
    """OraclePredictor{noise, refine_exact: true, seed} — multiplicative
    log-normal noise on the true output length, exact refinement."""

    name = "oracle"

    def __init__(self, noise, seed):
        self.noise = noise
        self.rng = SplitMix64(seed)

    def init_request(self, r):
        # One normal draw per admission, in admission order (skipped
        # entirely at noise 0 — Req.__init__ already holds the truth).
        if self.noise != 0.0:
            z = normal_from_uniform(self.rng.next_f64())
            est = max(float(r.n_out) * math.exp(self.noise * z), 1.0)
            r.initial_pred = est
            r.pred_remaining = est

    def on_token(self, r):
        r.pred_remaining = max(float(r.n_out - r.generated), 0.0)

    def observe_completion(self, r):
        pass


class ArenaProbePred:
    """ArenaProbe — a frozen offline probe: log-normal noise around the
    observed-class midpoint, static countdown refinement."""

    name = "probe"

    def __init__(self, noise, seed):
        self.noise = noise
        self.rng = SplitMix64(seed)

    def init_request(self, r):
        z = normal_from_uniform(self.rng.next_f64())
        est = max(BINS.midpoint(r.observed) * math.exp(self.noise * z), 1.0)
        r.initial_pred = est
        r.pred_remaining = est

    def on_token(self, r):
        r.pred_remaining = max(r.initial_pred - float(r.generated), 0.0)

    def observe_completion(self, r):
        pass


class BucketPred:
    """BucketPredictor — deterministic classifier: the observed-class
    midpoint exactly, static countdown refinement."""

    name = "bucket"

    def init_request(self, r):
        est = BINS.midpoint(r.observed)
        r.initial_pred = est
        r.pred_remaining = est

    def on_token(self, r):
        r.pred_remaining = max(r.initial_pred - float(r.generated), 0.0)

    def observe_completion(self, r):
        pass


class RankPred:
    """RankOnlyPredictor — comparable ordinal scores (observed class +
    1), never absolute lengths: Kendall-τ survives any monotone drift
    of the truth while MAE is meaningless by construction."""

    name = "rank"

    def init_request(self, r):
        est = float(r.observed + 1)
        r.initial_pred = est
        r.pred_remaining = est

    def on_token(self, r):
        pass

    def observe_completion(self, r):
        pass


class OnlinePred:
    """OnlinePredictor — per-bucket EMA posteriors re-fit from observed
    completions mid-run (the ELIS feedback loop); buckets with zero
    observations fall back to the midpoint instead of dividing by an
    empty count."""

    name = "online"

    def __init__(self):
        self.post = [0.0] * BINS.n_bins
        self.seen = [False] * BINS.n_bins

    def init_request(self, r):
        b = r.observed
        est = self.post[b] if self.seen[b] else BINS.midpoint(b)
        r.initial_pred = est
        r.pred_remaining = est

    def on_token(self, r):
        r.pred_remaining = max(r.initial_pred - float(r.generated), 0.0)

    def observe_completion(self, r):
        b = r.observed
        x = float(r.n_out)
        if self.seen[b]:
            self.post[b] = (1.0 - ONLINE_ALPHA) * self.post[b] + ONLINE_ALPHA * x
        else:
            self.post[b] = x
            self.seen[b] = True


def build_predictor(spec, noise, seed):
    """PredictorSpec::build — spec is None (oracle default) or a
    ("oracle"|"probe"|"bucket"|"rank"|"online",) tuple."""
    kind = spec[0] if spec is not None else "oracle"
    if kind == "oracle":
        return OraclePred(noise, seed)
    if kind == "probe":
        return ArenaProbePred(noise, seed)
    if kind == "bucket":
        return BucketPred()
    if kind == "rank":
        return RankPred()
    if kind == "online":
        return OnlinePred()
    raise ValueError(f"unknown predictor spec {spec!r}")


def pred_quality(pairs):
    """(kendall_tau, inversion_rate, mae, n) over (initial prediction,
    truth) pairs — τ-b with tie corrections, D/(C+D) over comparable
    pairs, MAE accumulated in recorded order. Non-finite pairs are
    dropped; fewer than two survivors yields all-zero quality. Mirrors
    arena.rs pred_quality op for op."""
    pts = [(p, t) for (p, t) in pairs if math.isfinite(p) and math.isfinite(t)]
    n = len(pts)
    if n < 2:
        return 0.0, 0.0, 0.0, n
    acc = 0.0
    for (p, t) in pts:
        acc += abs(p - t)
    mae = acc / float(n)
    conc = 0
    disc = 0
    tie_p = 0
    tie_t = 0
    for i in range(n):
        pi, ti = pts[i]
        for j in range(i + 1, n):
            dp = pi - pts[j][0]
            dt = ti - pts[j][1]
            if dp == 0.0:
                tie_p += 1
            if dt == 0.0:
                tie_t += 1
            if dp != 0.0 and dt != 0.0:
                if (dp > 0.0) == (dt > 0.0):
                    conc += 1
                else:
                    disc += 1
    n0 = n * (n - 1) // 2
    denom = math.sqrt(float(n0 - tie_p) * float(n0 - tie_t))
    tau = 0.0 if denom <= 0.0 else float(conc - disc) / denom
    inv = 0.0 if conc + disc == 0 else float(disc) / float(conc + disc)
    return tau, inv, mae, n


# ---------------------------------------------------------------------------
# Fairness layer (rust/src/coordinator/fairness.rs + Policy::rank_aged)
# ---------------------------------------------------------------------------


class FairCfg:
    """FairnessConfig mirror: starvation-guard quantum/boost/levels +
    per-tenant share weights. Neutral defaults switch everything off."""

    __slots__ = ("quantum", "boost", "levels", "weights")

    def __init__(self, quantum=0.0, boost=0.0, levels=0, weights=()):
        self.quantum = quantum
        self.boost = boost
        self.levels = levels
        self.weights = tuple(weights)

    def guard_active(self):
        return self.quantum > 0.0 and self.boost > 0.0 and self.levels > 0

    def shares_active(self):
        return len(self.weights) > 0

    def weight(self, t):
        return self.weights[t] if t < len(self.weights) else 1.0

    def mode_label(self):
        guard, shares = self.guard_active(), self.shares_active()
        if guard and shares:
            return "guard+shares"
        if guard:
            return "guard"
        if shares:
            return "shares"
        return "off"


NEUTRAL_FAIR = FairCfg()


def rank_fair(policy, r, fair):
    """Policy::rank_aged — the base rank with the starvation-guard boost
    folded into the key; bit-identical to rank() at level 0."""
    rk = rank(policy, r)
    if r.starve_level == 0:
        return rk
    return (rk[0], rk[1] - fair.boost * float(r.starve_level), rk[2], rk[3])


def policy_preemptive(policy):
    return policy[0] == "trail"


def policy_c(policy):
    return policy[1] if policy[0] == "trail" else 1.0


def policy_name(policy):
    if policy[0] == "fcfs":
        return "fcfs"
    if policy[0] == "sjf":
        return "sjf-prompt"
    c = policy[1]
    return "trail-c" + (str(int(c)) if c == int(c) else repr(c))


# ---------------------------------------------------------------------------
# Incremental rank index (rust/src/coordinator/rank_index.rs)
# ---------------------------------------------------------------------------
#
# A lazy bucket queue over quantized rank keys with a pairing-heap
# fallback for unbounded keys (locked = -inf tier, negative keys,
# overflow / non-finite keys). Entries are (rank, version) pairs; updates
# push a fresh version eagerly and leave the old entry to be skipped
# lazily at pop time, so pop order is always the exact total rank order
# regardless of internal shape. The `ops` counter is the selector work
# metric pinned into BENCH_sched.json: +1 per entry pushed (insert /
# update-with-change / reinsert / rebuild), +1 per update rank check,
# +1 per remove, +1 per physical entry examined by pop (stale or live).

RANK_BUCKET_WIDTH = 1.0
MAX_BUCKETS = 4096
HEAP_NONE = -1


class PairingHeap:
    """Arena pairing heap over (rank, version) entries; `maxdir` reverses
    the comparator. Mirrors rust/src/coordinator/rank_index.rs node for
    node (child/sibling links, two-pass merge pop)."""

    def __init__(self, maxdir):
        self.maxdir = maxdir
        self.entries = []   # entry payloads
        self.child = []
        self.sibling = []
        self.free = []
        self.root = HEAP_NONE

    def _less(self, a, b):
        return (a > b) if self.maxdir else (a < b)

    def _alloc(self, e):
        if self.free:
            n = self.free.pop()
            self.entries[n] = e
            self.child[n] = HEAP_NONE
            self.sibling[n] = HEAP_NONE
            return n
        self.entries.append(e)
        self.child.append(HEAP_NONE)
        self.sibling.append(HEAP_NONE)
        return len(self.entries) - 1

    def _meld(self, a, b):
        if a == HEAP_NONE:
            return b
        if b == HEAP_NONE:
            return a
        if self._less(self.entries[b], self.entries[a]):
            a, b = b, a
        self.sibling[b] = self.child[a]
        self.child[a] = b
        return a

    def push(self, e):
        self.root = self._meld(self.root, self._alloc(e))

    def pop(self):
        if self.root == HEAP_NONE:
            return None
        n = self.root
        e = self.entries[n]
        # Two-pass merge of the child chain.
        pairs = []
        c = self.child[n]
        while c != HEAP_NONE:
            nxt = self.sibling[c]
            self.sibling[c] = HEAP_NONE
            if nxt != HEAP_NONE:
                nn = self.sibling[nxt]
                self.sibling[nxt] = HEAP_NONE
                pairs.append(self._meld(c, nxt))
                c = nn
            else:
                pairs.append(c)
                break
        root = HEAP_NONE
        for p in reversed(pairs):
            root = self._meld(root, p)
        self.root = root
        self.entries[n] = None
        self.free.append(n)
        return e

    def clear(self):
        self.entries = []
        self.child = []
        self.sibling = []
        self.free = []
        self.root = HEAP_NONE


class RankIndex:
    """Incremental priority index over policy ranks; pop order is exactly
    the sorted rank order (min-first, or max-first when `maxdir`)."""

    def __init__(self, maxdir=False, width=RANK_BUCKET_WIDTH):
        self.maxdir = maxdir
        self.width = width
        # Grown on demand up to MAX_BUCKETS (mirrors the Rust index).
        self.buckets = []
        # Next candidate bucket for pop: min direction scans upward from
        # cursor, max direction scans downward.
        self.cursor = MAX_BUCKETS if not maxdir else 0
        self.front = PairingHeap(maxdir)   # locked entries (-inf tier)
        self.under = PairingHeap(maxdir)   # finite keys < 0
        self.over = PairingHeap(maxdir)    # keys >= MAX_BUCKETS*width, non-finite
        self.live = {}                     # rid -> (rank, version)
        self.vgen = 0
        self.len = 0
        self.n_entries = 0                 # physical entries incl. stale
        self.ops = 0

    # --- internal ---

    def _pop_less(self, a, b):
        return (a > b) if self.maxdir else (a < b)

    def _push_entry(self, e):
        self.ops += 1
        self.n_entries += 1
        rank = e[0]
        locked, key = rank[0] == 0, rank[1]
        if locked:
            self.front.push(e)
            return
        if not math.isfinite(key):
            (self.under if key < 0.0 else self.over).push(e)
            return
        if key < 0.0:
            self.under.push(e)
            return
        b = int(math.floor(key / self.width))
        if b >= MAX_BUCKETS:
            self.over.push(e)
            return
        while len(self.buckets) <= b:
            self.buckets.append([])
        bucket = self.buckets[b]
        # Keep the bucket sorted descending in pop order (last element
        # pops next); binary search for the unique insertion point.
        lo, hi = 0, len(bucket)
        while lo < hi:
            mid = (lo + hi) // 2
            if self._pop_less(e, bucket[mid]):
                lo = mid + 1
            else:
                hi = mid
        bucket.insert(lo, e)
        if not self.maxdir:
            if b < self.cursor:
                self.cursor = b
        else:
            if b > self.cursor:
                self.cursor = b

    def _is_live(self, e):
        cur = self.live.get(e[0][3])
        return cur is not None and cur[1] == e[1]

    def _maybe_compact(self):
        if self.n_entries > 4 * self.len + 64:
            for bucket in self.buckets:
                del bucket[:]
            self.front.clear()
            self.under.clear()
            self.over.clear()
            self.cursor = MAX_BUCKETS if not self.maxdir else 0
            self.n_entries = 0
            for rid in self.live:
                rank, version = self.live[rid]
                self._push_entry((rank, version))

    # --- public ---

    def insert(self, rid, rank):
        assert rid not in self.live, f"rank index: duplicate insert of rid {rid}"
        self._maybe_compact()
        version = self.vgen
        self.vgen += 1
        self.live[rid] = (rank, version)
        self.len += 1
        self._push_entry((rank, version))

    def update(self, rid, rank):
        cur = self.live.get(rid)
        assert cur is not None, f"rank index: update of absent rid {rid}"
        self.ops += 1
        if cur[0] == rank:
            return
        self._maybe_compact()
        version = self.vgen
        self.vgen += 1
        self.live[rid] = (rank, version)
        self._push_entry((rank, version))

    def remove(self, rid):
        assert rid in self.live, f"rank index: remove of absent rid {rid}"
        self.ops += 1
        del self.live[rid]
        self.len -= 1

    def reinsert(self, e):
        """Put back an entry returned by pop (same rank + version)."""
        rid = e[0][3]
        assert rid not in self.live, f"rank index: reinsert of live rid {rid}"
        self._maybe_compact()
        self.live[rid] = (e[0], e[1])
        self.len += 1
        self._push_entry(e)

    def _pop_heap(self, heap):
        while True:
            e = heap.pop()
            if e is None:
                return None
            self.ops += 1
            self.n_entries -= 1
            if self._is_live(e):
                del self.live[e[0][3]]
                self.len -= 1
                return e

    def pop(self):
        """Remove and return the next entry in pop order, or None."""
        order = (
            [self.over, None, self.under, self.front]
            if self.maxdir
            else [self.front, self.under, None, self.over]
        )
        for tier in order:
            if tier is not None:
                e = self._pop_heap(tier)
                if e is not None:
                    return e
                continue
            # Bucket tier: scan from the cursor.
            if not self.buckets:
                continue
            while True:
                if not self.maxdir:
                    while self.cursor < len(self.buckets) and not self.buckets[self.cursor]:
                        self.cursor += 1
                    if self.cursor >= len(self.buckets):
                        break
                else:
                    while self.cursor > 0 and not self.buckets[self.cursor]:
                        self.cursor -= 1
                    if not self.buckets[self.cursor]:
                        break
                bucket = self.buckets[self.cursor]
                found = None
                while bucket:
                    e = bucket.pop()
                    self.ops += 1
                    self.n_entries -= 1
                    if self._is_live(e):
                        del self.live[e[0][3]]
                        self.len -= 1
                        found = e
                        break
                if found is not None:
                    return found
        return None


class Kv:
    """rust/src/coordinator/kv.rs (incl. the prefix-sharing trie).

    The Rust trie stores refcounted block nodes keyed by exact content
    under a parent chain, so a node's identity is its full token prefix.
    The mirror keys blocks by that prefix directly —
    ``tuple(prompt[:(b+1)*PREFIX_BLOCK]) -> refcount`` — which is
    observably identical: same match lengths, same refcounts, same
    running ``savings``. ``alloc`` is a linear first-free scan, matching
    the Rust min-heap's lowest-free-index order."""

    def __init__(self, n_slots, pool_tokens):
        self.n_slots = n_slots
        self.pool_tokens = pool_tokens
        self.slots = [None] * n_slots
        self.charged = [0] * n_slots
        # Prefix cache state (inert unless enable_prefix_cache ran).
        self.prefix_on = False
        self.trie = {}                  # chain tuple -> refcount
        self.savings = 0                # Σ (refcount-1) * PREFIX_BLOCK
        self.prompts = [None] * n_slots
        self.nblocks = [0] * n_slots    # published full blocks per slot
        self.prefix_hits = 0
        self.reused_tokens = 0

    def enable_prefix_cache(self):
        assert all(s is None for s in self.slots), "prefix cache on a non-empty pool"
        self.prefix_on = True

    def used_tokens(self):
        return sum(self.charged) - self.savings

    def free_slot_available(self):
        return any(s is None for s in self.slots)

    def alloc(self, rid):
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = rid
                self.charged[i] = 0
                return i
        return None

    # --- prefix trie (KvManager::{set_prompt, shared_prefix_len,
    #     shared_tokens} + PrefixIndex::{add_ref, drop_ref, match_len}) ---

    def _block_key(self, slot, b):
        return tuple(self.prompts[slot][: (b + 1) * PREFIX_BLOCK])

    def _add_ref(self, key):
        n = self.trie.get(key)
        if n is None:
            self.trie[key] = 1
        else:
            self.trie[key] = n + 1
            self.savings += PREFIX_BLOCK

    def _drop_ref(self, key):
        n = self.trie[key]
        if n > 1:
            self.trie[key] = n - 1
            self.savings -= PREFIX_BLOCK
        else:
            del self.trie[key]

    def set_prompt(self, slot, rid, prompt):
        assert self.slots[slot] == rid, "slot not owned"
        if not self.prefix_on:
            return
        assert self.nblocks[slot] == 0, "set_prompt on a slot with live blocks"
        self.prompts[slot] = list(prompt)

    def shared_prefix_len(self, prompt):
        if not self.prefix_on:
            return 0
        matched = 0
        while (matched + 1) * PREFIX_BLOCK <= len(prompt):
            if tuple(prompt[: (matched + 1) * PREFIX_BLOCK]) not in self.trie:
                break
            matched += 1
        return matched * PREFIX_BLOCK

    def shared_tokens(self, slot):
        if not self.prefix_on:
            return 0
        n = 0
        for b in range(self.nblocks[slot]):
            if self.trie[self._block_key(slot, b)] >= 2:
                n += 1
        return n * PREFIX_BLOCK

    def _sync_blocks(self, slot, tokens):
        covered = min(tokens, len(self.prompts[slot]))
        want = covered // PREFIX_BLOCK
        while self.nblocks[slot] > want:
            self.nblocks[slot] -= 1
            self._drop_ref(self._block_key(slot, self.nblocks[slot]))
        while self.nblocks[slot] < want:
            self._add_ref(self._block_key(slot, self.nblocks[slot]))
            self.nblocks[slot] += 1

    def charge(self, slot, rid, tokens):
        assert self.slots[slot] == rid, "slot not owned"
        assert tokens <= MAX_SEQ
        self.charged[slot] = tokens
        if self.prefix_on:
            self._sync_blocks(slot, tokens)

    def free(self, slot, rid):
        assert self.slots[slot] == rid, "slot not owned"
        self.slots[slot] = None
        self.charged[slot] = 0
        if self.prefix_on:
            while self.nblocks[slot] > 0:
                self.nblocks[slot] -= 1
                self._drop_ref(self._block_key(slot, self.nblocks[slot]))
            self.prompts[slot] = None

    def fits(self, extra):
        return self.used_tokens() + extra <= self.pool_tokens


# ---------------------------------------------------------------------------
# Flight recorder (rust/src/obs/{trace,timing}.rs — byte-format mirror)
# ---------------------------------------------------------------------------
#
# Events are (t, rep, seq, rid, kind, payload) tuples; sorting the merged
# multi-replica stream by (t, rep, seq) is the same total order
# `obs::sort_events` uses, and `event_line` renders the same compact
# sorted-key JSON bytes as `TraceEvent::to_line` (bools travel as 0/1
# numbers so both writers agree). Wall-clock timing mirrors the
# PhaseTimer shape for `--timings-json` but is never byte-compared —
# only counts and cost-model virtual totals are pinned.

TRACE_SCHEMA = "trail.trace/v1"
TIMING_SCHEMA = "trail.timing/v1"

U64_MASK = (1 << 64) - 1

# obs::PHASE_ORDER — canonical phase order for reports.
PHASE_ORDER = [
    "select_targets", "ensure_resident", "resolve_oom", "rank_index",
    "dispatch", "prefill", "decode", "readout", "step",
]


def fnv1a64(data):
    """FNV-1a 64 over bytes (obs::fnv1a64 — the trace fingerprint)."""
    h = 0xcbf29ce484222325
    for b in data:
        h = ((h ^ b) * 0x100000001b3) & U64_MASK
    return h


def event_line(ev):
    """TraceEvent::to_line — one compact JSON object, lexicographically
    sorted keys (Rust renders through a BTreeMap)."""
    t, rep, seq, rid, kind, payload = ev
    fields = dict(payload)
    fields["t"] = t
    fields["rep"] = rep
    fields["seq"] = seq
    fields["rid"] = rid
    parts = []
    for k in sorted(fields.keys() | {"kind"}):
        if k == "kind":
            parts.append('"kind":"' + kind + '"')
        else:
            parts.append('"' + k + '":' + jnum(fields[k]))
    return "{" + ",".join(parts) + "}"


def sort_events(events):
    """obs::sort_events — (t, rep, seq) total order (all t finite)."""
    events.sort(key=lambda e: (e[0], e[1], e[2]))


def render_trace(events, cell=None):
    """obs::render_trace — schema header line (tagged with the grid cell
    when given), then one event per line, all newline-terminated."""
    if cell is None:
        header = '{"schema":"' + TRACE_SCHEMA + '"}'
    else:
        header = '{"cell":"' + cell + '","schema":"' + TRACE_SCHEMA + '"}'
    lines = [header]
    lines.extend(event_line(ev) for ev in events)
    return "\n".join(lines) + "\n"


def new_phase_counts():
    """obs::PhaseCounts::default — deterministic per-phase call counters."""
    return {
        "select_targets": 0, "ensure_resident": 0, "resolve_oom": 0,
        "prefill_chunks": 0, "decode_steps": 0, "decode_slot_steps": 0,
        "readouts": 0, "rank_index_ops": 0, "dispatch": 0, "steps": 0,
    }


def merge_phase_counts(acc, other):
    for k in acc:
        acc[k] += other[k]


def phase_rows(counts):
    """PhaseCounts::phases under CostModel::default() — (name, calls,
    virtual_s) in PHASE_ORDER. Scheduling phases are bookkeeping (no
    backend call), virtual total 0 by construction; backend phases
    derive theirs exactly the way the virtual clock charged them."""
    return [
        ("select_targets", counts["select_targets"], 0.0),
        ("ensure_resident", counts["ensure_resident"], 0.0),
        ("resolve_oom", counts["resolve_oom"], 0.0),
        ("rank_index", counts["rank_index_ops"], 0.0),
        ("dispatch", counts["dispatch"], 0.0),
        ("prefill", counts["prefill_chunks"],
         float(counts["prefill_chunks"]) * COST_PREFILL_CHUNK),
        ("decode", counts["decode_steps"],
         float(counts["decode_steps"]) * COST_DECODE_STEP
         + float(counts["decode_slot_steps"]) * COST_DECODE_PER_SLOT),
        ("readout", counts["readouts"],
         float(counts["readouts"]) * COST_READOUT),
        ("step", counts["steps"], 0.0),
    ]


class TimingStats:
    """obs::TimingStats — wall-clock span aggregates. Structural mirror
    only: wall time is never byte-compared (it would break the frozen
    reports), it just makes `--timings-json` and the <5% self-overhead
    acceptance bound checkable from the mirror too."""

    def __init__(self):
        self.spans = {}            # name -> [calls, inclusive_s, self_s]
        self.n_spans = 0
        self.overhead_per_span = 0.0

    def merge(self, other):
        for name, (c, incl, slf) in other.spans.items():
            e = self.spans.setdefault(name, [0, 0.0, 0.0])
            e[0] += c
            e[1] += incl
            e[2] += slf
        self.n_spans += other.n_spans
        self.overhead_per_span = max(self.overhead_per_span,
                                     other.overhead_per_span)

    def overhead_s(self):
        return float(self.n_spans) * self.overhead_per_span

    def total_wall_s(self):
        if "step" in self.spans:
            return self.spans["step"][1]
        return sum(v[2] for v in self.spans.values())

    def overhead_frac(self):
        total = self.total_wall_s()
        return self.overhead_s() / total if total > 0.0 else 0.0


class PhaseTimer:
    """obs::PhaseTimer — hierarchical wall timer; a child's inclusive
    time is subtracted from the parent's self time. Constructing one
    calibrates the per-span overhead on the spot."""

    def __init__(self):
        n = 4096
        t0 = time.perf_counter()
        for _ in range(n):
            s = time.perf_counter()
            _ = time.perf_counter() - s
        per_span = (time.perf_counter() - t0) / float(n)
        self.stack = []            # [phase, start, child_seconds]
        self._stats = TimingStats()
        self._stats.overhead_per_span = per_span

    def enter(self, phase):
        self.stack.append([phase, time.perf_counter(), 0.0])

    def exit(self):
        if not self.stack:
            return
        phase, start, child_s = self.stack.pop()
        incl = time.perf_counter() - start
        slf = max(incl - child_s, 0.0)
        e = self._stats.spans.setdefault(phase, [0, 0.0, 0.0])
        e[0] += 1
        e[1] += incl
        e[2] += slf
        self._stats.n_spans += 1
        if self.stack:
            self.stack[-1][2] += incl

    def stats(self):
        out = TimingStats()
        out.merge(self._stats)
        return out


def timing_report_text(counts, stats=None):
    """obs::timing_report_json rendered to text — deterministic phase
    rows (calls + virtual totals) joined with wall measurements when a
    timer ran, sorted-key JSON + newline."""
    rows = []
    for name, calls, vt in phase_rows(counts):
        wall_calls, wall_s, self_s = (0, 0.0, 0.0)
        if stats is not None and name in stats.spans:
            wall_calls, wall_s, self_s = stats.spans[name]
        rows.append({
            "name": name, "calls": calls, "virtual_s": vt,
            "wall_calls": wall_calls, "wall_s": wall_s, "self_s": self_s,
        })
    doc = {"schema": TIMING_SCHEMA, "phases": rows}
    if stats is not None:
        doc["total_wall_s"] = stats.total_wall_s()
        doc["overhead_s"] = stats.overhead_s()
        doc["overhead_frac"] = stats.overhead_frac()
        doc["n_spans"] = stats.n_spans
    return row_json(doc) + "\n"


class Engine:
    """Virtual-clock ServingEngine<MockBackend> with the oracle predictor
    (multiplicative log-normal noise on the initial estimate, exact
    refinement per token — OraclePredictor{noise, refine_exact, seed})."""

    def __init__(self, policy, slots, pool_tokens, noise=0.4, pred_seed=7,
                 max_iterations=2_000_000, selector="indexed", fair=NEUTRAL_FAIR,
                 prefix_cache=False, predictor=None, obs=None, cost_mult=1.0):
        self.policy = policy
        self.slots = slots
        # CostModel::scaled — heterogeneous hardware generations scale
        # every cost constant once at construction (docs/fleet.md). The
        # default 1.0 is bit-identical to the unscaled constants, which
        # is what keeps every pre-fleet baseline byte-frozen.
        self.c_decode_step = COST_DECODE_STEP * cost_mult
        self.c_decode_slot = COST_DECODE_PER_SLOT * cost_mult
        self.c_prefill = COST_PREFILL_CHUNK * cost_mult
        self.c_readout = COST_READOUT * cost_mult
        self.kv = Kv(slots, pool_tokens)
        if prefix_cache:
            self.kv.enable_prefix_cache()
        self.noise = noise
        self.predictor = build_predictor(predictor, noise, pred_seed)
        self.now = 0.0
        self.reqs = []
        self.finished_rids = []
        self.pending_cost = 0.0
        self.n_iter = 0
        self.max_iterations = max_iterations
        # Incremental rank index (always maintained; read when
        # selector == "indexed") + the reference selector's scan counter.
        self.selector = selector
        self.sched_idx = RankIndex(maxdir=False)
        self.res_idx = RankIndex(maxdir=True)
        self.sel_ops_ref = 0
        # rid -> position in self.reqs, maintained incrementally (the
        # Rust RidSlab: admit appends, migration swap-removes, post-step
        # compaction fixes the suffix past the first finished request).
        self.rid_pos = {}
        # Fairness layer: knobs + per-tenant deficit share ledger.
        self.fair = fair
        self.t_live = []
        self.t_credit = []
        # metrics
        self.lat = []
        self.ttft = []
        self.n_finished = 0
        self.m_preemptions = 0
        self.m_discards = 0
        self.m_migrations = 0
        self.peak_mem = 0
        self.max_wait_age = 0.0
        # Metrics::pred_pairs — (initial prediction, truth) in finish order.
        self.pred_pairs = []
        # Flight recorder (rust obs::EngineObs): obs is None or a
        # (trace, timing, replica) tuple; inert (no state at all, every
        # helper a no-op) unless trace or timing is on — exactly the
        # `serve.obs.enabled()` gate in the Rust engine.
        self.obs = None
        if obs is not None and (obs[0] or obs[1]):
            self.obs = {
                "trace_on": obs[0],
                "replica": obs[2],
                "seq": 0,
                "events": [],
                "counts": new_phase_counts(),
                "timer": PhaseTimer() if obs[1] else None,
            }

    # --- flight recorder (no-ops when obs is inert) ---
    def tracing(self):
        return self.obs is not None and self.obs["trace_on"]

    def trace(self, t, rid, kind, payload=None):
        o = self.obs
        if o is not None and o["trace_on"]:
            o["events"].append((t, o["replica"], o["seq"], rid, kind,
                                payload if payload is not None else {}))
            o["seq"] += 1

    def obs_count(self, key, n=1):
        if self.obs is not None:
            self.obs["counts"][key] += n

    def obs_enter(self, phase):
        if self.obs is not None and self.obs["timer"] is not None:
            self.obs["timer"].enter(phase)

    def obs_exit(self):
        if self.obs is not None and self.obs["timer"] is not None:
            self.obs["timer"].exit()

    def take_trace(self):
        if self.obs is None:
            return []
        events = self.obs["events"]
        self.obs["events"] = []
        return events

    def phase_counts(self):
        if self.obs is None:
            return new_phase_counts()
        return dict(self.obs["counts"])

    def timing_stats(self):
        if self.obs is not None and self.obs["timer"] is not None:
            return self.obs["timer"].stats()
        return None

    # --- clock ---
    def sync_clock(self, at):
        if at > self.now:
            self.now = at

    # --- status ---
    def any_schedulable(self):
        return any(r.phase != FINISHED for r in self.reqs)

    def live(self):
        return sum(1 for r in self.reqs if r.phase != FINISHED)

    def resident(self):
        return sum(1 for r in self.reqs if r.phase != FINISHED and r.slot is not None)

    def pred_sum(self):
        s = 0.0
        for r in self.reqs:
            if r.phase != FINISHED:
                s += max(r.pred_remaining, 0.0)
        return s

    def admit(self, req):
        # Predictor::init_request (for the noisy predictors: one normal
        # draw per admission, in admission order, from this engine's
        # predictor stream).
        self.predictor.init_request(req)
        self.trace(req.arrival, req.rid, "admit", {
            "tenant": req.tenant, "prompt": req.plen,
            "predicted": req.initial_pred})
        self.sched_idx.insert(req.rid, self.rank_of(req))
        self.rid_pos[req.rid] = len(self.reqs)
        self.shares_on_admit(req.tenant)
        self.reqs.append(req)

    def selector_ops(self):
        if self.selector == "reference":
            return self.sel_ops_ref
        return self.sched_idx.ops + self.res_idx.ops

    def rank_of(self, r):
        return rank_fair(self.policy, r, self.fair)

    def reindex(self, r):
        self.obs_count("rank_index_ops")
        rk = self.rank_of(r)
        self.sched_idx.update(r.rid, rk)
        if r.slot is not None:
            self.res_idx.update(r.rid, rk)

    # --- fairness: tenant share ledger (coordinator/fairness.rs) ---
    def shares_ensure(self, tenant):
        while len(self.t_live) < tenant + 1:
            self.t_live.append(0)
            self.t_credit.append(0.0)

    def shares_on_admit(self, tenant):
        self.shares_ensure(tenant)
        self.t_live[tenant] += 1

    def shares_on_remove(self, tenant):
        self.shares_ensure(tenant)
        self.t_live[tenant] -= 1

    def shares_accrue(self):
        wsum = 0.0
        for t in range(len(self.t_live)):
            if self.t_live[t] > 0:
                wsum += self.fair.weight(t)
        if wsum <= 0.0:
            return
        cap = float(2 * self.slots)
        for t in range(len(self.t_live)):
            if self.t_live[t] == 0:
                self.t_credit[t] = 0.0
            else:
                add = float(self.slots) * self.fair.weight(t) / wsum
                self.t_credit[t] = min(self.t_credit[t] + add, cap)

    def shares_can_take(self, tenant):
        if tenant >= len(self.t_credit):
            return True
        return self.t_credit[tenant] >= 1.0

    def shares_take(self, tenant):
        self.shares_ensure(tenant)
        cap = float(2 * self.slots)
        self.t_credit[tenant] = max(self.t_credit[tenant] - 1.0, -cap)

    # --- fairness: starvation guard (ServingEngine::refresh_starvation) ---
    def refresh_starvation(self, reqs):
        fair = self.fair
        if not fair.guard_active():
            return
        now = self.now
        q = fair.quantum
        cap = float(fair.levels)
        for r in reqs:
            if r.phase == FINISHED:
                continue
            level = int(max(min(math.floor((now - r.wait_started) / q), cap), 0.0))
            if level != r.starve_level:
                r.starve_level = level
                self.reindex(r)

    # --- migration (rust ServingEngine::take_migratable) ---
    def take_migratable(self):
        pick = None  # (resident, rank, idx)
        for i, r in enumerate(self.reqs):
            if r.phase == FINISHED:
                continue
            rk = self.rank_of(r)
            if rk[0] == 0:  # locked
                continue
            res = r.slot is not None
            if pick is None:
                better = True
            else:
                pres, prank, _ = pick
                if res != pres:
                    better = not res
                else:
                    better = rk > prank
            if better:
                pick = (res, rk, i)
        if pick is None:
            return None
        idx = pick[2]
        # Vec::swap_remove, with the rid slab fixed up for the moved tail
        if idx == len(self.reqs) - 1:
            r = self.reqs.pop()
        else:
            r = self.reqs[idx]
            self.reqs[idx] = self.reqs.pop()
            self.rid_pos[self.reqs[idx].rid] = idx
        del self.rid_pos[r.rid]
        self.shares_on_remove(r.tenant)
        self.sched_idx.remove(r.rid)
        if r.slot is not None:
            self.kv.free(r.slot, r.rid)
            self.res_idx.remove(r.rid)
            r.slot = None
        r.prefilled = 0
        r.kv_written = 0
        r.phase = WAITING if r.generated == 0 else DISCARDED
        r.n_migrations += 1
        self.trace(self.now, r.rid, "migrate_out")
        return r

    def admit_migrated(self, r):
        self.trace(self.now, r.rid, "migrate_in")
        self.sched_idx.insert(r.rid, self.rank_of(r))
        self.rid_pos[r.rid] = len(self.reqs)
        self.shares_on_admit(r.tenant)
        self.reqs.append(r)

    # --- crash teardown (rust ServingEngine::take_all_for_crash) ---
    def take_all_for_crash(self):
        """Drain *every* unfinished request, in vector order, exactly as
        take_migratable strips one — KV freed, prefill progress zeroed,
        phase reset for recomputation elsewhere. Unlike migration no
        migrate_out events are traced and no migrated-out counters move:
        the replica is dead, not cooperating (docs/fleet.md)."""
        out = []
        reqs = self.reqs
        self.reqs = []
        for r in reqs:
            if r.phase == FINISHED:
                continue
            self.shares_on_remove(r.tenant)
            self.sched_idx.remove(r.rid)
            del self.rid_pos[r.rid]
            if r.slot is not None:
                self.kv.free(r.slot, r.rid)
                self.res_idx.remove(r.rid)
                r.slot = None
            r.prefilled = 0
            r.kv_written = 0
            r.phase = WAITING if r.generated == 0 else DISCARDED
            r.n_migrations += 1
            out.append(r)
        return out

    # --- step (rust step/step_inner) ---
    def step(self):
        if not self.any_schedulable():
            return False, []
        if self.max_iterations > 0 and self.n_iter >= self.max_iterations:
            raise RuntimeError("max_iterations exceeded — scheduler stall?")
        reqs = self.reqs
        self.obs_enter("step")
        # Starvation guard first, so eviction and selection both see
        # aged ranks; then OOM resolution; then the per-step tenant
        # credit accrual the share-capped selection draws from.
        self.refresh_starvation(reqs)
        self.obs_enter("resolve_oom")
        self.resolve_oom(reqs)
        self.obs_exit()
        self.obs_count("resolve_oom")
        if self.fair.shares_active():
            self.shares_accrue()
        self.obs_enter("select_targets")
        if self.selector == "indexed":
            target = self.select_targets_indexed(reqs)
        else:
            target = self.select_targets(reqs)
        self.obs_exit()
        self.obs_count("select_targets")

        # ---- prefill budget ----
        self.obs_enter("prefill")
        prefill_done_now = []
        budget = PREFILL_CHUNKS_PER_ITER
        chunks_issued = 0
        for idx in target:
            if budget == 0:
                break
            r = reqs[idx]
            if r.prefill_done():
                continue
            while budget > 0 and not r.prefill_done():
                tokens_len = r.prefill_target()
                start = r.prefilled
                nvalid = min(tokens_len - start, CHUNK)
                if not self.kv.fits(nvalid):
                    break
                self.pending_cost += self.c_prefill
                r.prefilled += nvalid
                r.kv_written = r.prefilled
                self.kv.charge(r.slot, r.rid, r.kv_written)
                budget -= 1
                chunks_issued += 1
            self.kv.charge(r.slot, r.rid, r.kv_written)
            if r.prefill_done():
                prefill_done_now.append(idx)
        self.obs_exit()
        self.obs_count("prefill_chunks", chunks_issued)

        # ---- decode ----
        decoding = []
        for idx in target:
            r = reqs[idx]
            if (
                r.phase == RUNNING
                and r.prefill_done()
                and r.generated >= 1
                and idx not in prefill_done_now
            ):
                decoding.append(idx)
        if decoding:
            self.obs_enter("decode")
            self.pending_cost += self.c_decode_step + self.c_decode_slot * len(decoding)
            self.obs_exit()
            self.obs_count("decode_steps")
            self.obs_count("decode_slot_steps", len(decoding))

        # ---- readout + clock ----
        stepped = bool(decoding) or bool(prefill_done_now)
        if stepped:
            self.obs_enter("readout")
            self.pending_cost += self.c_readout
            self.obs_exit()
            self.obs_count("readouts")
        cost = self.pending_cost
        self.pending_cost = 0.0
        self.now += cost
        now = self.now

        if stepped:
            for idx in prefill_done_now:
                r = reqs[idx]
                first = r.generated == 0
                if first:
                    r.generated = 1
                    r.first_token_at = now
                self.kv.charge(r.slot, r.rid, r.kv_written)
                self.trace(now, r.rid, "prefill_done")
                if first:
                    self.trace(now, r.rid, "first_token")
                self.finish_if_done(r, now)
                if r.phase != FINISHED:
                    self.reindex(r)
            for idx in decoding:
                r = reqs[idx]
                r.kv_written = max(r.kv_written, r.plen + r.generated - 1 + 1)
                r.generated += 1
                self.predictor.on_token(r)
                self.kv.charge(r.slot, r.rid, r.kv_written)
                self.finish_if_done(r, now)
                if r.phase != FINISHED:
                    self.reindex(r)

        used = self.kv.used_tokens()
        if used > self.peak_mem:
            self.peak_mem = used
        self.n_iter += 1

        finished = []
        for rid in self.finished_rids:
            r = next(r for r in reqs if r.rid == rid)
            finished.append((rid, r.finished_at - r.arrival, r.first_token_at - r.arrival, r.generated))
        self.finished_rids = []
        # The step span closes where Rust `step_inner` returns: the
        # post-step compaction below is outside it.
        self.obs_exit()
        self.obs_count("steps")
        if finished:
            # Order-preserving compaction with incremental slab
            # maintenance (rust ServingEngine::step); steps that finish
            # nothing skip it entirely.
            w = 0
            for i in range(len(reqs)):
                r = reqs[i]
                if r.phase == FINISHED:
                    del self.rid_pos[r.rid]
                else:
                    if w != i:
                        reqs[w] = r
                        self.rid_pos[r.rid] = w
                    w += 1
            del reqs[w:]
        worked = stepped or chunks_issued > 0
        return worked, finished

    def finish_if_done(self, r, now):
        if r.done() and r.phase != FINISHED:
            r.finished_at = now
            r.phase = FINISHED
            if r.slot is not None:
                self.kv.free(r.slot, r.rid)
                self.res_idx.remove(r.rid)
                r.slot = None
            self.sched_idx.remove(r.rid)
            self.shares_on_remove(r.tenant)
            self.predictor.observe_completion(r)
            # Metrics::observe_finish
            self.n_finished += 1
            self.lat.append(r.finished_at - r.arrival)
            self.ttft.append(r.first_token_at - r.arrival)
            self.m_preemptions += r.n_preemptions
            self.m_discards += r.n_discards
            self.m_migrations += r.n_migrations
            self.pred_pairs.append((r.initial_pred, float(r.n_out)))
            self.finished_rids.append(r.rid)
            self.trace(now, r.rid, "finish", {
                "latency": r.finished_at - r.arrival,
                "ttft": (r.first_token_at - r.arrival)
                        if r.first_token_at is not None else 0.0,
                "toks": r.generated})

    # --- prefix-aware victim ranking (ServingEngine::victim_rank) ---
    def victim_rank(self, r, base):
        """Bias eviction toward residents whose KV is mostly shared —
        their discard frees little real memory but costs little to
        redo, since the shared blocks stay attachable. Identity when
        the prefix cache is off, so legacy benches see exact ranks."""
        if not self.kv.prefix_on:
            return base
        if r.slot is None:
            return base
        shared = self.kv.shared_tokens(r.slot)
        if shared == 0:
            return base
        return (base[0], base[1] + PREFIX_VICTIM_BONUS_PER_TOKEN * shared,
                base[2], base[3])

    def oom_victim_indexed(self, reqs):
        """ServingEngine::oom_victim_indexed: ops-free scan of the live
        resident-index cache (no pop machinery — selector_ops stays
        exactly what the frozen benches recorded), preferring
        preemptable victims, strict max by prefix-adjusted rank."""
        c = policy_c(self.policy)
        best_pre = None
        best_any = None
        for rid, (cached, _ver) in self.res_idx.live.items():
            i = self.rid_pos[rid]
            r = reqs[i]
            rk = self.victim_rank(r, cached)
            if best_any is None or rk > best_any[0]:
                best_any = (rk, i)
            if r.preemptable(c) and (best_pre is None or rk > best_pre[0]):
                best_pre = (rk, i)
        pick = best_pre if best_pre is not None else best_any
        return None if pick is None else pick[1]

    def resolve_oom(self, reqs):
        if self.kv.fits(0):
            return
        if self.selector == "indexed":
            while not self.kv.fits(0):
                vi = self.oom_victim_indexed(reqs)
                if vi is None:
                    break
                self.discard_victim(reqs[vi], in_res_idx=True, oom=True)
            return
        c = policy_c(self.policy)
        while not self.kv.fits(0):
            cands = [
                (i, r)
                for i, r in enumerate(reqs)
                if r.slot is not None and r.phase != FINISHED and r.preemptable(c)
            ]
            if not cands:
                cands = [
                    (i, r)
                    for i, r in enumerate(reqs)
                    if r.slot is not None and r.phase != FINISHED
                ]
            if not cands:
                break
            _, r = max(cands, key=lambda t: self.victim_rank(t[1], self.rank_of(t[1])))
            self.discard_victim(r, in_res_idx=True, oom=True)

    def discard_victim(self, r, in_res_idx, oom=False):
        """ServingEngine::discard_victim: KV dropped, recompute later. A
        share-deferred candidate can be discarded while its entry sits
        popped-and-held by the in-flight selection; its rank is
        invariant under the discard (only TRAIL discards mid-selection),
        so the held entry stays valid — the index just must not be
        updated for a rid it doesn't hold. `oom` tags the trace event:
        pool exhaustion vs an admission-time eviction decision."""
        self.kv.free(r.slot, r.rid)
        if in_res_idx:
            self.res_idx.remove(r.rid)
        r.slot = None
        r.phase = DISCARDED
        r.prefilled = 0
        r.kv_written = 0
        r.n_discards += 1
        if r.rid in self.sched_idx.live:
            self.sched_idx.update(r.rid, self.rank_of(r))
        self.trace(self.now, r.rid, "discard", {"oom": 1 if oom else 0})

    def apply_phase_transitions(self, reqs, chosen, now):
        for i, r in enumerate(reqs):
            before = r.phase
            level_before = r.starve_level
            preempted = False
            if not chosen[i] and r.phase == RUNNING:
                r.phase = PREEMPTED
                r.n_preemptions += 1
                preempted = True
            elif chosen[i] and r.phase in (PREEMPTED, WAITING, DISCARDED):
                r.phase = RUNNING if r.prefill_done() else PREFILLING
            elif chosen[i] and r.phase == PREFILLING and r.prefill_done():
                r.phase = RUNNING
            if chosen[i]:
                if before in (WAITING, PREEMPTED, DISCARDED):
                    age = now - r.wait_started
                    if age > self.max_wait_age:
                        self.max_wait_age = age
                r.wait_started = now
                r.starve_level = 0
            if r.phase != before or r.starve_level != level_before:
                self.reindex(r)
            if preempted:
                self.trace(now, r.rid, "preempt")

    def select_targets(self, reqs):
        shares_on = self.fair.shares_active()
        order = [i for i in range(len(reqs)) if reqs[i].phase != FINISHED]
        order.sort(key=lambda i: self.rank_of(reqs[i]))
        self.sel_ops_ref += len(order)
        now = self.now
        target = []
        chosen = [False] * len(reqs)
        deferred = []
        for idx in order:
            if len(target) >= self.slots:
                break
            if shares_on:
                rk = self.rank_of(reqs[idx])
                if rk[0] == 1 and not self.shares_can_take(reqs[idx].tenant):
                    deferred.append(idx)
                    continue
            if self.ensure_resident(reqs, idx, chosen):
                chosen[idx] = True
                target.append(idx)
                if shares_on:
                    self.shares_take(reqs[idx].tenant)
        # Second pass: leftover slots go to deferred candidates in rank
        # order (work-conserving deficit round-robin).
        for idx in deferred:
            if len(target) >= self.slots:
                break
            if self.ensure_resident(reqs, idx, chosen):
                chosen[idx] = True
                target.append(idx)
                self.shares_take(reqs[idx].tenant)
        self.apply_phase_transitions(reqs, chosen, now)
        return target

    def select_targets_indexed(self, reqs):
        shares_on = self.fair.shares_active()
        now = self.now
        target = []
        chosen = [False] * len(reqs)
        held = []
        deferred = []
        while len(target) < self.slots:
            ent = self.sched_idx.pop()
            if ent is None:
                break
            idx = self.rid_pos[ent[0][3]]
            if shares_on and ent[0][0] == 1 and not self.shares_can_take(reqs[idx].tenant):
                deferred.append(ent)
                continue
            if self.ensure_resident_indexed(reqs, idx, chosen):
                chosen[idx] = True
                target.append(idx)
                if shares_on:
                    self.shares_take(reqs[idx].tenant)
            held.append(ent)
        for ent in deferred:
            if len(target) >= self.slots:
                break
            idx = self.rid_pos[ent[0][3]]
            if self.ensure_resident_indexed(reqs, idx, chosen):
                chosen[idx] = True
                target.append(idx)
                self.shares_take(reqs[idx].tenant)
        for ent in held:
            self.sched_idx.reinsert(ent)
        for ent in deferred:
            self.sched_idx.reinsert(ent)
        self.apply_phase_transitions(reqs, chosen, now)
        return target

    # --- prefix-aware admission (ServingEngine::{admission_need,
    #     attachable_prefix, alloc_slot}) ---
    def attachable_prefix(self, r):
        """Whole shared blocks attachable at admission, capped one token
        short of the prefill target (rounded down to a block) so the
        first-token readout still has work to do."""
        if not self.kv.prefix_on:
            return 0
        matched = self.kv.shared_prefix_len(r.prompt)
        cap = (r.prefill_target() - 1) // PREFIX_BLOCK * PREFIX_BLOCK
        return min(matched, cap)

    def admission_need(self, r):
        return min(r.prefill_target() - self.attachable_prefix(r), MAX_SEQ)

    def alloc_slot(self, r):
        slot = self.kv.alloc(r.rid)
        assert slot is not None
        r.slot = slot
        r.prefilled = 0
        r.kv_written = 0
        attached = 0
        if self.kv.prefix_on:
            self.kv.set_prompt(slot, r.rid, r.prompt)
            attach = self.attachable_prefix(r)
            if attach > 0:
                r.prefilled = attach
                r.kv_written = attach
                self.kv.charge(slot, r.rid, attach)
                self.kv.prefix_hits += 1
                self.kv.reused_tokens += attach
                attached = attach
        rk = self.rank_of(r)
        self.res_idx.insert(r.rid, rk)
        if self.tracing():
            credit = (self.t_credit[r.tenant]
                      if r.tenant < len(self.t_credit) else 0.0)
            self.trace(self.now, r.rid, "sched_alloc", {
                "key": rk[1], "locked": 1 if rk[0] == 0 else 0,
                "starve": r.starve_level, "credit": credit,
                "attach": attached})

    def preempt_victim_prefix(self, reqs, idx, chosen, c):
        """Prefix-aware admission victim: live-cache scan with the
        shared-token bonus, same Greater/EVICT_MARGIN gates as the pop
        path. Only reached when the prefix cache is on."""
        best = None
        for rid, (cached, _ver) in self.res_idx.live.items():
            i = self.rid_pos[rid]
            r = reqs[i]
            if chosen[i] or r.phase == FINISHED or not r.preemptable(c):
                continue
            rk = self.victim_rank(r, cached)
            if best is None or rk > best[0]:
                best = (rk, i)
        if best is None:
            return None
        vr, vi = best
        cr = self.rank_of(reqs[idx])
        if not vr > cr:
            return None
        if vr[0] == 1 and cr[0] == 1 and vr[1] - cr[1] < EVICT_MARGIN:
            return None
        return vi

    def ensure_resident(self, reqs, idx, chosen):
        self.obs_count("ensure_resident")
        if reqs[idx].slot is not None:
            return True
        c = policy_c(self.policy)
        need = self.admission_need(reqs[idx])
        while True:
            have_slot = self.kv.free_slot_available()
            have_mem = self.kv.fits(min(need, CHUNK * 2))
            if have_slot and have_mem:
                break
            self.sel_ops_ref += len(reqs)
            victims = [
                (i, r)
                for i, r in enumerate(reqs)
                if not chosen[i]
                and r.slot is not None
                and r.phase != FINISHED
                and policy_preemptive(self.policy)
                and r.preemptable(c)
            ]
            if not victims:
                return False
            _, vreq = max(victims, key=lambda t: self.victim_rank(t[1], self.rank_of(t[1])))
            vr = self.victim_rank(vreq, self.rank_of(vreq))
            cr = self.rank_of(reqs[idx])
            if not vr > cr:
                return False
            if vr[0] == 1 and cr[0] == 1 and vr[1] - cr[1] < EVICT_MARGIN:
                return False
            self.trace(self.now, reqs[idx].rid, "sched_evict", {
                "key": cr[1], "vrid": vreq.rid, "vkey": vr[1]})
            self.discard_victim(vreq, in_res_idx=True)
        self.alloc_slot(reqs[idx])
        return True

    def ensure_resident_indexed(self, reqs, idx, chosen):
        self.obs_count("ensure_resident")
        if reqs[idx].slot is not None:
            return True
        need = self.admission_need(reqs[idx])
        while True:
            have_slot = self.kv.free_slot_available()
            have_mem = self.kv.fits(min(need, CHUNK * 2))
            if have_slot and have_mem:
                break
            if not policy_preemptive(self.policy):
                return False
            if self.kv.prefix_on:
                # Prefix-adjusted ranks reorder victims relative to the
                # raw index order, so the pop machinery can't serve them;
                # scan the live cache instead (same victim the Rust
                # preempt_victim_prefix picks).
                c = policy_c(self.policy)
                vi = self.preempt_victim_prefix(reqs, idx, chosen, c)
                if vi is None:
                    return False
                if self.tracing():
                    vkey = self.victim_rank(reqs[vi], self.rank_of(reqs[vi]))[1]
                    key = self.rank_of(reqs[idx])[1]
                    self.trace(self.now, reqs[idx].rid, "sched_evict", {
                        "key": key, "vrid": reqs[vi].rid, "vkey": vkey})
                self.discard_victim(reqs[vi], in_res_idx=True)
                continue
            # Worst-ranked eligible victim: pop the resident max index;
            # chosen entries are skipped, a locked entry means no
            # unlocked resident remains (locked sorts last max-first).
            held = []
            victim = None
            while True:
                e = self.res_idx.pop()
                if e is None:
                    break
                if e[0][0] == 0:
                    held.append(e)
                    break
                if chosen[self.rid_pos[e[0][3]]]:
                    held.append(e)
                    continue
                victim = e
                break
            cr = self.rank_of(reqs[idx])
            ok = (
                victim is not None
                and victim[0] > cr
                and not (
                    victim[0][0] == 1
                    and cr[0] == 1
                    and victim[0][1] - cr[1] < EVICT_MARGIN
                )
            )
            if not ok:
                if victim is not None:
                    self.res_idx.reinsert(victim)
                for e in held:
                    self.res_idx.reinsert(e)
                return False
            for e in held:
                self.res_idx.reinsert(e)
            vreq = reqs[self.rid_pos[victim[0][3]]]
            self.trace(self.now, reqs[idx].rid, "sched_evict", {
                "key": cr[1], "vrid": victim[0][3], "vkey": victim[0][1]})
            # The victim was already popped off the resident index.
            self.discard_victim(vreq, in_res_idx=False)
        self.alloc_slot(reqs[idx])
        return True


# ---------------------------------------------------------------------------
# Trace workload (rust/src/workload/trace.rs)
# ---------------------------------------------------------------------------

def tenant_arrivals(rate, phases, n, rng):
    out = []
    t = 0.0
    phase_idx = 0
    if not phases:
        cur_rate, phase_left = rate, float("inf")
    else:
        cur_rate, phase_left = rate * phases[0][0], phases[0][1]
    while len(out) < n:
        e = -math.log(1.0 - rng.next_f64())
        while True:
            if cur_rate > 0.0 and e <= cur_rate * phase_left:
                dt = e / cur_rate
                t += dt
                phase_left -= dt
                out.append(t)
                break
            e -= cur_rate * phase_left
            t += phase_left
            phase_idx = (phase_idx + 1) % len(phases)
            phase_left = phases[phase_idx][1]
            cur_rate = rate * phases[phase_idx][0]
    return out


class TenantGen:
    """WorkloadGen mirror, reduced to (plen, n_out): the oracle co-sim
    never reads token values, and the per-request child stream is split
    off the master, so skipping token draws does not perturb anything."""

    def __init__(self, seed, mu_shift):
        self.seed = seed
        self.master = SplitMix64(seed)
        self.w = replace(WORKLOAD, lognormal_mu=WORKLOAD.lognormal_mu + mu_shift)

    def next_request(self):
        rng = self.master.split()
        # sample_output_len
        z = normal_from_uniform(rng.next_f64())
        x = math.exp(self.w.lognormal_mu + self.w.lognormal_sigma * z)
        n = int(x + 0.5)
        n_out = min(max(n, self.w.min_output), self.w.max_output)
        # observed_class: the same single uniform the pre-arena mirror
        # discarded — the prompt sees the true class only noisily
        # (gen.rs observed_class, the arena predictors' sole feature).
        cls = BINS.bin_of(float(n_out))
        zc = normal_from_uniform(rng.next_f64())
        obs = cls + f64_round(self.w.class_jitter_sigma * zc)
        obs = min(max(obs, 0), BINS.n_bins - 1)
        plen = rng.next_range(self.w.min_prompt, self.w.max_prompt)
        return plen, n_out, obs

    # --- prefix-sharing workload (WorkloadGen::{prefix_templates,
    #     next_prefix_request}, rust/src/workload/gen.rs) ---

    def prefix_templates(self, spec):
        """Templates drawn from a salted stream derived from the tenant
        seed — zero draws on the master, so mixing prefix and legacy
        tenants in one trace perturbs nothing."""
        n_templates, prefix_len = spec[0], spec[1]
        rng = SplitMix64(self.seed ^ PREFIX_TEMPLATE_SALT)
        lo, hi = MODEL.first_content_id, MODEL.vocab - 1
        out = []
        for _ in range(n_templates):
            t = [MODEL.bos_id]
            for _ in range(prefix_len - 1):
                t.append(rng.next_range(lo, hi))
            out.append(t)
        return out

    def next_prefix_request(self, spec, templates):
        """Unlike next_request there is no observed_class draw; the
        draw order on the child stream is output-len, share coin,
        template index, tail length, then token draws. Response draws
        follow on the discarded child stream — skipping them is exact."""
        _n_templates, prefix_len, share_p, tail_min, tail_max = spec
        rng = self.master.split()
        z = normal_from_uniform(rng.next_f64())
        x = math.exp(self.w.lognormal_mu + self.w.lognormal_sigma * z)
        n = int(x + 0.5)
        n_out = min(max(n, self.w.min_output), self.w.max_output)
        shared = rng.next_f64() < share_p
        t_idx = rng.next_range(0, len(templates) - 1)
        tail_len = rng.next_range(tail_min, tail_max)
        lo, hi = MODEL.first_content_id, MODEL.vocab - 1
        if shared:
            prompt = list(templates[t_idx])
        else:
            prompt = [MODEL.bos_id]
            for _ in range(prefix_len - 1):
                prompt.append(rng.next_range(lo, hi))
        for _ in range(tail_len):
            prompt.append(rng.next_range(lo, hi))
        # Prompt + output must fit one slot (gen.rs clamps the same way:
        # prefix prompts outgrow the legacy max_prompt bound).
        n_out = max(min(n_out, MAX_SEQ - len(prompt)), 1)
        # No prompt-time jitter draw on the prefix path: the observed
        # class is the post-clamp true bin, with zero extra draws
        # (gen.rs next_prefix_request sets observed_class the same way).
        return len(prompt), n_out, prompt, BINS.bin_of(float(n_out))


def prefix_agentic(share_p):
    """PrefixSpec::agentic — few long templates, short tails."""
    return (4, 96, share_p, 16, 48)


def prefix_rag(share_p):
    """PrefixSpec::rag — many medium templates, longer tails."""
    return (16, 64, share_p, 24, 64)


def generate_trace(tenants, n, seed):
    """tenants: list of (rate, mu_shift, phases[, prefix_spec[, drift]])
    — phases: [(mult, dur)]; drift: (at, mu_delta, jitter_sigma) flips
    the true output-length distribution of that (legacy) tenant's
    requests arriving at/after `at` — a multiplicative log-normal shift
    drawn from a salted side stream, so zero draws land on the master
    or child streams and every pre-drift / legacy byte is untouched.
    The prompt-time observed class keeps describing the *pre-drift*
    truth: that stale feature is exactly what the predictor arena has
    to survive. Entries are (at, tenant, rid, plen, n_out, prompt,
    observed); prompt is None for legacy tenants (the co-sim never
    reads their token values)."""
    master = SplitMix64(seed)
    streams = []
    for tenant in tenants:
        rate, mu_shift, phases = tenant[0], tenant[1], tenant[2]
        prefix = tenant[3] if len(tenant) > 3 else None
        drift = tenant[4] if len(tenant) > 4 else None
        spec_seed = master.next_u64()
        arr_rng = SplitMix64(master.next_u64())
        times = tenant_arrivals(rate, phases, n, arr_rng)
        gen = TenantGen(spec_seed, mu_shift)
        templates = gen.prefix_templates(prefix) if prefix is not None else None
        drift_rng = SplitMix64(spec_seed ^ DRIFT_SALT) if drift is not None else None
        streams.append([times, gen, 0, prefix, templates, drift, drift_rng])
    out = []
    while len(out) < n:
        best = None
        for ti, stream in enumerate(streams):
            at = stream[0][stream[2]]
            if best is None or at < best[0]:
                best = (at, ti)
        at, ti = best
        stream = streams[ti]
        stream[2] += 1
        if stream[3] is not None:
            plen, n_out, prompt, obs = stream[1].next_prefix_request(stream[3], stream[4])
        else:
            plen, n_out, obs = stream[1].next_request()
            prompt = None
        drift = stream[5]
        if drift is not None and stream[3] is None and at >= drift[0]:
            # WorkloadGen::apply_drift — shift the already-drawn truth;
            # the child split regenerates the response tokens in Rust
            # (token values never reach the co-sim, so the mirror only
            # advances the side stream).
            rng = stream[6]
            z = normal_from_uniform(rng.next_f64())
            x = float(n_out) * math.exp(drift[1] + drift[2] * z)
            w = stream[1].w
            n_out = min(max(int(x + 0.5), w.min_output), w.max_output)
            rng.split()
        out.append((at, ti, len(out), plen, n_out, prompt, obs))
    return out


# ---------------------------------------------------------------------------
# Driver (rust/src/sim/driver.rs)
# ---------------------------------------------------------------------------

def pick_replica(dispatch, engines, rr, prompt=None):
    if dispatch == "rr":
        return rr % len(engines)
    if dispatch == "jsq":
        return min(range(len(engines)), key=lambda i: (engines[i].live(), i))
    if dispatch == "affinity" and prompt is not None:
        # DispatchPolicy::pick_with_affinity — the co-sim queries the
        # engines' tries exactly; best match wins ties by shorter queue
        # then lower index, and loses to least-work when taking it would
        # skew queues past the imbalance guard.
        lens = [e.kv.shared_prefix_len(prompt) for e in engines]
        best = None
        for i in range(len(engines)):
            if lens[i] < AFFINITY_MIN_MATCH:
                continue
            key = (lens[i], -engines[i].live(), -i)
            if best is None or key > best[0]:
                best = (key, i)
        if best is not None:
            min_queued = min(e.live() for e in engines)
            if engines[best[1]].live() <= min_queued + AFFINITY_QUEUE_IMBALANCE:
                return best[1]
    # least-work (unseen is always 0 on the co-sim path)
    return min(
        range(len(engines)),
        key=lambda i: (engines[i].pred_sum(), engines[i].live(), i),
    )


def run_sim(trace, policy, replicas, dispatch, migration, slots, pool_tokens, noise=0.4,
            selector="indexed", fair=NEUTRAL_FAIR, prefix_cache=False, predictor=None,
            obs=None):
    # obs = (trace_on, timing_on); each engine gets its replica index
    # stamped so merged events sort the same way the Rust driver's do.
    engines = [
        Engine(policy, slots, pool_tokens, noise=noise, selector=selector, fair=fair,
               prefix_cache=prefix_cache, predictor=predictor,
               obs=(obs[0], obs[1], i) if obs is not None else None)
        for i in range(replicas)
    ]
    n_total = len(trace)
    nxt = 0
    rr = 0
    n_migrations = 0
    lat = []
    ttft = []
    finished = 0
    stalled = [False] * replicas
    rid_tenant = {e[2]: e[1] for e in trace}
    n_tenants = max((e[1] for e in trace), default=-1) + 1
    tenant_lat = [[] for _ in range(n_tenants)]
    tenant_ttft = [[] for _ in range(n_tenants)]
    tenant_slow = [[] for _ in range(n_tenants)]

    def rebalance(now):
        nonlocal n_migrations
        moved = False
        while True:
            idle = next((j for j in range(replicas) if not engines[j].any_schedulable()), None)
            if idle is None:
                break
            donors = []  # (waiting, k)
            for k in range(replicas):
                if k == idle:
                    continue
                waiting = engines[k].live() - engines[k].resident()
                if waiting <= 0 or (engines[k].resident() == 0 and waiting < 2):
                    continue
                donors.append((waiting, k))
            donors.sort(key=lambda t: (-t[0], t[1]))
            migrated = False
            for _, k in donors:
                req = engines[k].take_migratable()
                if req is None:
                    continue
                engines[idle].sync_clock(now)
                engines[idle].admit_migrated(req)
                stalled[idle] = False
                stalled[k] = False
                n_migrations += 1
                moved = True
                migrated = True
                break
            if not migrated:
                break
        return moved

    while True:
        active = None
        for i, e in enumerate(engines):
            if stalled[i] or not e.any_schedulable():
                continue
            now = e.now
            if active is None or now < active[0]:
                active = (now, i)

        if nxt < n_total and (active is None or trace[nxt][0] <= active[0]):
            at, tenant, rid, plen, n_out, prompt, obs = trace[nxt]
            nxt += 1
            idx = pick_replica(dispatch, engines, rr, prompt)
            rr += 1
            engines[idx].sync_clock(at)
            engines[idx].admit(Req(rid, plen, n_out, tenant, at, prompt, obs))
            stalled[idx] = False
            continue

        if active is None:
            if any(e.any_schedulable() for e in engines):
                now = max(0.0, *[e.now for e in engines])
                if migration and rebalance(now):
                    continue
                raise RuntimeError("co-sim stalled")
            break

        now, i = active
        if migration and rebalance(now):
            continue
        worked, fin = engines[i].step()
        if not worked:
            stalled[i] = True
        for (rid, l, t, ntok) in fin:
            finished += 1
            lat.append(l)
            ttft.append(t)
            tenant_lat[rid_tenant[rid]].append(l)
            tenant_ttft[rid_tenant[rid]].append(t)
            # max(ntok, 1): a zero-token completion must not poison the
            # slowdown percentiles with NaN/inf (mirrors record_finish).
            tenant_slow[rid_tenant[rid]].append(l / float(max(ntok, 1)))

    assert finished == n_total, f"lost requests: {finished}/{n_total}"
    makespan = max(e.now for e in engines)
    max_starve = 0.0
    for e in engines:
        if e.max_wait_age > max_starve:
            max_starve = e.max_wait_age
    # Replica-index order concatenation (finish order within each
    # engine) — the Rust driver aggregates the same way, so the MAE
    # float-sum order matches exactly.
    pred_pairs = []
    for e in engines:
        pred_pairs.extend(e.pred_pairs)
    # Flight recorder: concatenate per-engine traces in replica-index
    # order, then virtual-time sort — mirrors SimDriver::finish_obs.
    trace_events = []
    counts = new_phase_counts()
    timing = None
    for e in engines:
        trace_events.extend(e.take_trace())
        merge_phase_counts(counts, e.phase_counts())
        ts = e.timing_stats()
        if ts is not None:
            if timing is None:
                timing = ts
            else:
                timing.merge(ts)
    counts["dispatch"] += rr
    sort_events(trace_events)
    return {
        "trace_events": trace_events,
        "phase_counts": counts,
        "timing": timing,
        "predictor": engines[0].predictor.name,
        "pred_pairs": pred_pairs,
        "n": finished,
        "lat": lat,
        "ttft": ttft,
        "preemptions": sum(e.m_preemptions for e in engines),
        "discards": sum(e.m_discards for e in engines),
        "migrations": n_migrations,
        "kv_peak": max(e.peak_mem for e in engines),
        "per_replica": [e.n_finished for e in engines],
        "makespan": makespan,
        "iters": sum(e.n_iter for e in engines),
        "sel_ops": sum(e.selector_ops() for e in engines),
        "tenant_lat": tenant_lat,
        "tenant_ttft": tenant_ttft,
        "tenant_slow": tenant_slow,
        "max_starve": max_starve,
        "prefix_hits": sum(e.kv.prefix_hits for e in engines),
        "reused_tokens": sum(e.kv.reused_tokens for e in engines),
    }


# ---------------------------------------------------------------------------
# Fleet dynamics (rust/src/sim/fleet.rs + SimDriver::run_fleet —
# docs/fleet.md; keep every rule in sync!)
# ---------------------------------------------------------------------------

SLO_INTERACTIVE = 0
SLO_BATCH = 1


def default_fleet():
    """FleetConfig::default — inert: serves any trace byte-identically
    to the plain serial driver loop (no crashes, no scaling, fresh
    snapshots, every tenant interactive, homogeneous cost)."""
    return {
        "seed": 0xF1EE7,
        "failure_rate": 0.0,
        "horizon_s": 60.0,
        "recovery_s": 2.0,
        "redispatch": True,
        "autoscaler": False,
        "min_replicas": 1,
        "max_replicas": 0,
        "initial_up": 0,
        "boot_delay_s": 0.5,
        "check_interval_s": 0.25,
        "up_backlog": 8.0,
        "down_backlog": 1.0,
        "stale_s": 0.0,
        "slo_classes": [],
        "shed_queue": 0,
        "degrade_queue": 0,
        "degrade_cap": 24,
        "cost_mults": [],
    }


def fleet_class_of(fleet, tenant):
    """FleetConfig::class_of — clamped to the two known classes;
    missing entries are interactive."""
    classes = fleet["slo_classes"]
    if tenant >= len(classes):
        return SLO_INTERACTIVE
    return min(classes[tenant], SLO_BATCH)


def crash_schedule(seed, failure_rate, horizon_s):
    """fleet::crash_schedule — (time, target draw) pairs on
    [0, horizon_s); Exp(rate) gaps off one SplitMix64 stream, victim
    drawn at fire time from the draw modulo the up set."""
    out = []
    if failure_rate <= 0.0 or horizon_s <= 0.0:
        return out
    rng = SplitMix64(seed)
    t = 0.0
    while True:
        t += -math.log(1.0 - rng.next_f64()) / failure_rate
        if t >= horizon_s:
            return out
        out.append((t, rng.next_u64()))


def pick_active(dispatch, snaps, active, rr):
    """DispatchPolicy::pick_active — dispatch over the up, non-draining
    sub-pool from (possibly stale) snapshots `(queued, pred_remaining)`.
    Round-robin cycles the active set; JSQ/least-work break ties by
    global index (unseen is always 0 on the co-sim path, so estimated
    work is the published prediction mass). Cache-affinity is rejected
    by run_fleet_sim before this is ever reached."""
    if dispatch == "rr":
        return active[rr % len(active)]
    if dispatch == "jsq":
        return min(active, key=lambda i: (snaps[i][0], i))
    return min(active, key=lambda i: (snaps[i][1], snaps[i][0], i))


def run_fleet_sim(trace, policy, replicas, dispatch, slots, pool_tokens, fleet,
                  noise=0.4):
    """SimDriver::run_fleet — the serial event loop of run_sim extended
    with a third event source, the seeded fleet stream (crashes,
    boot/recovery completions, autoscaler ticks), interleaved with
    arrivals and engine steps in virtual-time order.

    Event interleaving: at equal times, fleet events fire before
    arrivals, which fire before steps; within the fleet stream,
    boot/recovery completions beat crashes beat autoscaler ticks, ties
    breaking to the lowest replica index. Conservation holds on exit:
    finished + shed + lost == arrivals."""
    if dispatch == "affinity":
        raise RuntimeError("cache-affinity dispatch is not supported under fleet dynamics")
    cost_mults = fleet["cost_mults"]
    engines = [
        Engine(policy, slots, pool_tokens, noise=noise,
               cost_mult=(cost_mults[i % len(cost_mults)] if cost_mults else 1.0))
        for i in range(replicas)
    ]
    n_rep = replicas
    n_total = len(trace)
    nxt = 0
    rr = 0
    n_migrations = 0
    lat = []
    ttft = []
    finished = 0
    stalled = [False] * n_rep
    rid_tenant = {e[2]: e[1] for e in trace}
    n_tenants = max((e[1] for e in trace), default=-1) + 1
    tenant_lat = [[] for _ in range(n_tenants)]
    tenant_ttft = [[] for _ in range(n_tenants)]
    tenant_slow = [[] for _ in range(n_tenants)]
    # Per-SLO-class latency pools for the interactive/batch p99 the
    # chaos grid pivots on.
    class_lat = [[], []]

    initial_up = n_rep if fleet["initial_up"] == 0 else min(fleet["initial_up"], n_rep)
    max_replicas = n_rep if fleet["max_replicas"] == 0 else min(fleet["max_replicas"], n_rep)
    min_replicas = min(max(fleet["min_replicas"], 1), max_replicas)
    up = [i < initial_up for i in range(n_rep)]
    draining = [False] * n_rep
    # Pending in-service transitions: (completion time, is_recovery)
    # per replica (autoscaler boots and crash recoveries).
    pending = [None] * n_rep
    crashes_sched = crash_schedule(fleet["seed"], fleet["failure_rate"], fleet["horizon_s"])
    crash_ptr = 0
    tick_k = 0

    n_crashes = 0
    recoveries = 0
    redispatched = 0
    lost = 0
    scale_ups = 0
    scale_downs = 0
    shed = 0
    degraded = 0
    up_now = initial_up
    up_min = up_now
    up_max = up_now

    # Propagated load signals (stale_s > 0): dispatch reads these,
    # bulk-refreshed from engine truth once per stale_s epoch. All
    # replicas start empty, so zeros are the t = 0 truth.
    stale_s = fleet["stale_s"]
    published = [(0, 0.0)] * n_rep
    last_epoch = [-1]

    def refresh_published(t):
        # Only up replicas publish — a down replica's last snapshot
        # goes stale with it, exactly like a real status plane.
        if stale_s <= 0.0:
            return
        epoch = math.floor(t / stale_s)
        if epoch == last_epoch[0]:
            return
        last_epoch[0] = epoch
        for i in range(n_rep):
            if up[i]:
                published[i] = (engines[i].live(), engines[i].pred_sum())

    def fleet_snaps():
        # Fresh mode recomputes per call, matching the serial loop's
        # semantics byte-for-byte (the snapshot read is pure).
        if stale_s > 0.0:
            return list(published)
        return [(e.live(), e.pred_sum()) for e in engines]

    while True:
        active = None
        for i, e in enumerate(engines):
            if not up[i] or stalled[i] or not e.any_schedulable():
                continue
            now = e.now
            if active is None or now < active[0]:
                active = (now, i)
        t_arr = trace[nxt][0] if nxt < n_total else None
        # Down replicas never hold work (crash strips everything; drain
        # completion requires an empty live set), so this is the
        # whole-fleet completion check.
        if t_arr is None and not any(
            up[i] and engines[i].any_schedulable() for i in range(n_rep)
        ):
            break

        # ---- next fleet event: (time, kind priority, replica) ----
        # `hard` events (boot/recovery completions, crashes) are a
        # finite stream and may fire even when everything is stalled;
        # autoscaler ticks recur forever and may not.
        fev_hard = None
        for i, p in enumerate(pending):
            if p is not None:
                k = (p[0], 0, i)
                if fev_hard is None or k < fev_hard:
                    fev_hard = k
        if crash_ptr < len(crashes_sched):
            k = (crashes_sched[crash_ptr][0], 1, 0)
            if fev_hard is None or k < fev_hard:
                fev_hard = k
        fev = fev_hard
        if fleet["autoscaler"]:
            k = ((tick_k + 1) * fleet["check_interval_s"], 2, 0)
            if fev is None or k < fev:
                fev = k

        mask = [i for i in range(n_rep) if up[i] and not draining[i]]
        if t_arr is None and active is None:
            # Work remains but every up engine is memory-stalled: only
            # a hard fleet event can change anything.
            if fev_hard is None:
                raise RuntimeError("co-sim stalled")
            chosen = fev_hard
        elif fev is not None:
            tf = fev[0]
            due = (t_arr is None or tf <= t_arr) and (active is None or tf <= active[0])
            if due:
                chosen = fev
            elif not mask and nxt < n_total:
                # Arrival into a total blackout: pull the next hard
                # event forward (the request waits at the door for the
                # boot/recovery) rather than dropping it.
                chosen = fev_hard
            else:
                chosen = None
        else:
            chosen = None

        if chosen is not None:
            tf, kind, r = chosen
            if kind == 0:
                # ---- boot / recovery completion ----
                _, is_recovery = pending[r]
                pending[r] = None
                up[r] = True
                stalled[r] = False
                engines[r].sync_clock(tf)
                # A fresh replica announces itself: its published
                # snapshot is re-read immediately.
                published[r] = (engines[r].live(), engines[r].pred_sum())
                if is_recovery:
                    recoveries += 1
                up_now += 1
                up_max = max(up_max, up_now)
            elif kind == 1:
                # ---- crash ----
                draw = crashes_sched[crash_ptr][1]
                crash_ptr += 1
                cands = [i for i in range(n_rep) if up[i]]
                if len(cands) <= 1:
                    # Never kill the last replica in service.
                    continue
                victim = cands[draw % len(cands)]
                up[victim] = False
                draining[victim] = False
                stalled[victim] = False
                n_crashes += 1
                up_now -= 1
                up_min = min(up_min, up_now)
                orphans = engines[victim].take_all_for_crash()
                mask_c = [i for i in range(n_rep) if up[i] and not draining[i]]
                if fleet["redispatch"] and mask_c:
                    refresh_published(tf)
                    for req in orphans:
                        snaps = fleet_snaps()
                        tgt = pick_active(dispatch, snaps, mask_c, rr)
                        rr += 1
                        engines[tgt].sync_clock(tf)
                        engines[tgt].admit_migrated(req)
                        stalled[tgt] = False
                        redispatched += 1
                else:
                    lost += len(orphans)
                if fleet["recovery_s"] > 0.0:
                    pending[victim] = (tf + fleet["recovery_s"], True)
            else:
                # ---- autoscaler tick ----
                tick_k += 1
                refresh_published(tf)
                snaps = fleet_snaps()
                backlog = sum(snaps[i][0] for i in mask)
                per = backlog / max(len(mask), 1)
                pending_boots = sum(1 for p in pending if p is not None)
                if (not mask or per >= fleet["up_backlog"]) and \
                        up_now + pending_boots < max_replicas:
                    r2 = next(
                        (i for i in range(n_rep) if not up[i] and pending[i] is None),
                        None,
                    )
                    if r2 is not None:
                        pending[r2] = (tf + fleet["boot_delay_s"], False)
                        scale_ups += 1
                elif per <= fleet["down_backlog"] and len(mask) > min_replicas \
                        and pending_boots == 0:
                    # Drain the highest-index dispatchable replica —
                    # with ascending cost_mults that is the slowest
                    # hardware generation.
                    r2 = mask[-1]
                    draining[r2] = True
                    scale_downs += 1
                # Drain pump: move every migratable request off
                # draining replicas; locked work finishes locally and
                # the replica leaves service at the first tick that
                # sees it empty.
                for r2 in range(n_rep):
                    if not draining[r2]:
                        continue
                    mask2 = [i for i in range(n_rep) if up[i] and not draining[i]]
                    if mask2:
                        while True:
                            req = engines[r2].take_migratable()
                            if req is None:
                                break
                            snaps2 = fleet_snaps()
                            tgt = pick_active(dispatch, snaps2, mask2, rr)
                            rr += 1
                            engines[tgt].sync_clock(tf)
                            engines[tgt].admit_migrated(req)
                            stalled[tgt] = False
                            stalled[r2] = False
                            n_migrations += 1
                    if engines[r2].live() == 0:
                        draining[r2] = False
                        up[r2] = False
                        up_now -= 1
                        up_min = min(up_min, up_now)
            continue

        # ---- arrivals due before the next step ----
        if nxt < n_total and (active is None or trace[nxt][0] <= active[0]):
            at, tenant, rid, plen, n_out, prompt, obs = trace[nxt]
            nxt += 1
            if not mask:
                # Total blackout with nothing pending (chosen would
                # have pulled a hard event forward otherwise): the
                # request has no door to wait at.
                lost += 1
                continue
            refresh_published(at)
            snaps = fleet_snaps()
            if fleet_class_of(fleet, tenant) == SLO_BATCH:
                # SLO admission control reads the same (possibly
                # stale) depth signal dispatch does.
                depth = sum(snaps[i][0] for i in mask)
                if fleet["shed_queue"] > 0 and depth >= fleet["shed_queue"]:
                    shed += 1
                    continue
                cap = max(fleet["degrade_cap"], 1)
                if fleet["degrade_queue"] > 0 and depth >= fleet["degrade_queue"] \
                        and n_out > cap:
                    n_out = cap
                    degraded += 1
            idx = pick_active(dispatch, snaps, mask, rr)
            rr += 1
            engines[idx].sync_clock(at)
            engines[idx].admit(Req(rid, plen, n_out, tenant, at, prompt, obs))
            stalled[idx] = False
            continue

        # ---- one step of the earliest up replica ----
        now, i = active
        worked, fin = engines[i].step()
        if not worked:
            stalled[i] = True
        for (rid, l, t, ntok) in fin:
            finished += 1
            lat.append(l)
            ttft.append(t)
            tenant_lat[rid_tenant[rid]].append(l)
            tenant_ttft[rid_tenant[rid]].append(t)
            tenant_slow[rid_tenant[rid]].append(l / float(max(ntok, 1)))
            class_lat[fleet_class_of(fleet, rid_tenant[rid])].append(l)

    # Conservation: every arrival is finished, shed, or lost — nothing
    # double-counted, nothing silently dropped.
    expected = n_total - shed - lost
    assert finished == expected, (
        f"fleet accounting broke: {finished} finished + {shed} shed + "
        f"{lost} lost != {n_total} arrivals"
    )
    makespan = max(e.now for e in engines)
    max_starve = 0.0
    for e in engines:
        if e.max_wait_age > max_starve:
            max_starve = e.max_wait_age
    pred_pairs = []
    for e in engines:
        pred_pairs.extend(e.pred_pairs)
    counts = new_phase_counts()
    counts["dispatch"] += rr
    return {
        "trace_events": [],
        "phase_counts": counts,
        "timing": None,
        "predictor": engines[0].predictor.name,
        "pred_pairs": pred_pairs,
        "n": finished,
        "lat": lat,
        "ttft": ttft,
        "preemptions": sum(e.m_preemptions for e in engines),
        "discards": sum(e.m_discards for e in engines),
        "migrations": n_migrations,
        "kv_peak": max(e.peak_mem for e in engines),
        "per_replica": [e.n_finished for e in engines],
        "makespan": makespan,
        "iters": sum(e.n_iter for e in engines),
        "sel_ops": sum(e.selector_ops() for e in engines),
        "tenant_lat": tenant_lat,
        "tenant_ttft": tenant_ttft,
        "tenant_slow": tenant_slow,
        "max_starve": max_starve,
        "prefix_hits": sum(e.kv.prefix_hits for e in engines),
        "reused_tokens": sum(e.kv.reused_tokens for e in engines),
        # FleetOutcome — the `fleet` section of a BENCH_fleet.json row.
        "fleet": {
            "arrivals": n_total,
            "crashes": n_crashes,
            "recoveries": recoveries,
            "redispatched": redispatched,
            "lost": lost,
            "scale_ups": scale_ups,
            "scale_downs": scale_downs,
            "shed": shed,
            "degraded": degraded,
            "up_min": up_min,
            "up_max": up_max,
            "interactive_p99_s": percentile(class_lat[0], 99.0) if class_lat[0] else 0.0,
            "batch_p99_s": percentile(class_lat[1], 99.0) if class_lat[1] else 0.0,
            "autoscaler": fleet["autoscaler"],
            "failure_rate": fleet["failure_rate"],
            "boot_delay_s": fleet["boot_delay_s"],
            "stale_s": fleet["stale_s"],
        },
    }


# ---------------------------------------------------------------------------
# Scenarios (rust/src/sim/scenario.rs builtins — keep in sync!)
# ---------------------------------------------------------------------------

def builtin_scenarios():
    # name -> (tenants, n, seed, dispatch, slots, pool_frac, noise)
    # Keep in sync with rust/src/sim/scenario.rs `builtin`.
    return {
        "steady": ([(170.0, 0.0, [])], 500, 9001, "jsq", 128, 0.55, 0.4),
        "bursty": ([(45.0, 0.0, [(4.0, 2.5), (0.2, 5.5)])], 500, 9001, "jsq", 128, 0.55, 0.4),
        "multi-tenant": (
            [
                (90.0, -0.3, []),
                (20.0, 0.9, []),
                (40.0, 0.0, [(2.0, 1.0), (0.5, 3.0)]),
            ],
            500, 9001, "jsq", 128, 0.55, 0.4,
        ),
        "skewed": (
            [
                (14.0, 1.0, [(4.0, 1.5), (0.1, 4.5)]),
                (26.0, -0.5, []),
            ],
            240, 9001, "rr", 16, 0.35, 0.8,
        ),
        # Scheduler-scale grid (BENCH_sched.json): the same ~2.5x-overload
        # mix at 1k and 10k requests on 4 replicas (per-replica live sets
        # grow into the thousands — the hot-path blow-up regime), plus a
        # 128-replica fleet point where per-replica sets stay small.
        "scale-1k": (
            [
                (288.0, -0.3, []),
                (72.0, 0.7, []),
            ],
            1000, 777, "jsq", 32, 0.55, 0.4,
        ),
        "scale-10k": (
            [
                (288.0, -0.3, []),
                (72.0, 0.7, []),
            ],
            10000, 777, "jsq", 32, 0.55, 0.4,
        ),
        # Million-request points (BENCH_scale.json): the same overload
        # mix under round-robin dispatch — the Rust driver's sharded
        # parallel path. scale-1m is on-demand only; the pinned baseline
        # stops at 100k so this mirror can regenerate it in-image.
        "scale-100k": (
            [
                (288.0, -0.3, []),
                (72.0, 0.7, []),
            ],
            100000, 777, "rr", 32, 0.55, 0.4,
        ),
        "scale-1m": (
            [
                (288.0, -0.3, []),
                (72.0, 0.7, []),
            ],
            1000000, 777, "rr", 32, 0.55, 0.4,
        ),
        "scale-replicas": (
            [(2100.0, 0.0, [])],
            2560, 777, "jsq", 16, 0.5, 0.4,
        ),
        # Fairness grid (BENCH_fair.json, docs/fairness.md): two-tenant
        # regimes where size-based scheduling starves the long tenant.
        "fair-steady": (
            [
                (240.0, -0.9, []),
                (35.0, 0.1, []),
            ],
            400, 4242, "jsq", 16, 0.45, 0.4,
        ),
        "fair-skewed": (
            [
                (170.0, -0.7, [(2.5, 1.0), (0.3, 2.0)]),
                (40.0, 0.0, []),
            ],
            400, 4242, "rr", 16, 0.4, 0.4,
        ),
        "fair-adversarial": (
            [
                (260.0, -0.9, []),
                (5.0, 1.3, []),
            ],
            400, 4242, "jsq", 16, 0.45, 0.0,
        ),
        "fair-fleet": (
            [
                (4500.0, -0.4, []),
                (1800.0, 0.6, []),
            ],
            2560, 777, "jsq", 8, 0.5, 0.4,
        ),
        # Predictor-arena grid (BENCH_pred.json, docs/predictors.md):
        # a two-tenant overloaded mix where scheduling quality hinges on
        # telling the short tenant from the long one. The drift variant
        # is byte-identical except tenant 0's true lengths flip (×e^1.2,
        # ~3.3x) at t=2.5 while its prompt-time observed class keeps
        # describing the old truth — the stale-feature regime only
        # online refresh (and the drift-immune rank scorer) survives.
        "pred-steady": (
            [
                (40.0, -0.2, []),
                (20.0, 0.4, []),
            ],
            400, 2718, "jsq", 16, 0.4, 0.4,
        ),
        "pred-drift": (
            [
                (40.0, -0.2, [], None, (2.5, 1.2, 0.2)),
                (20.0, 0.4, []),
            ],
            400, 2718, "jsq", 16, 0.4, 0.4,
        ),
        # Fleet chaos grid (BENCH_fleet.json, docs/fleet.md): a hot
        # interactive tenant (steady / diurnal / flash-crowd arrivals)
        # plus a steady batch tenant — on a 6-replica fleet of small
        # slots, 4 in service at t=0 and two cold spares on slower
        # hardware. The diurnal phases mirror TenantProfile::diurnal
        # (period 2 s over six graded steps); the flash phases mirror
        # TenantProfile::flash_crowd (baseline 1 s, 3x spike for 1 s,
        # baseline forever).
        "fleet-steady": (
            [
                (180.0, -0.3, []),
                (40.0, 0.8, []),
            ],
            600, 606, "jsq", 16, 0.5, 0.4,
        ),
        "fleet-diurnal": (
            [
                (150.0, -0.3, [(0.5, 2.0 / 6.0), (0.8, 2.0 / 6.0),
                               (1.3, 2.0 / 6.0), (1.6, 2.0 / 6.0),
                               (1.3, 2.0 / 6.0), (0.8, 2.0 / 6.0)]),
                (40.0, 0.8, []),
            ],
            600, 606, "jsq", 16, 0.5, 0.4,
        ),
        "fleet-flash": (
            [
                (120.0, -0.3, [(1.0, 1.0), (3.0, 1.0), (1.0, 1e9)]),
                (40.0, 0.8, []),
            ],
            600, 606, "jsq", 16, 0.5, 0.4,
        ),
    }


def scenario_tenant_names():
    # Keep in sync with the TenantProfile names in rust scenario.rs.
    return {
        "steady": ["poisson"],
        "bursty": ["diurnal"],
        "multi-tenant": ["chat", "batch", "background"],
        "skewed": ["heavy", "light"],
        "scale-1k": ["chat", "batch"],
        "scale-10k": ["chat", "batch"],
        "scale-100k": ["chat", "batch"],
        "scale-1m": ["chat", "batch"],
        "scale-replicas": ["fleet"],
        "fair-steady": ["interactive", "batch"],
        "fair-skewed": ["flood", "longtail"],
        "fair-adversarial": ["shorts", "longs"],
        "fair-fleet": ["hot", "tail"],
        "pred-steady": ["shifting", "stable"],
        "pred-drift": ["shifting", "stable"],
        "fleet-steady": ["interactive", "batch"],
        "fleet-diurnal": ["interactive", "batch"],
        "fleet-flash": ["interactive", "batch"],
    }


# ---------------------------------------------------------------------------
# Report serialisation (rust/src/sim/report.rs — byte-format mirror)
# ---------------------------------------------------------------------------

SCHEMA = "trail.simlab.bench/v1"
SCHED_SCHEMA = "trail.simlab.sched/v1"
FAIR_SCHEMA = "trail.simlab.fair/v1"


def jnum(x):
    x = float(x)
    assert math.isfinite(x)
    if x == math.trunc(x) and abs(x) < 1e15:
        return str(int(x))
    r = repr(x)
    if "e" in r or "E" in r:
        # Python repr() switches to scientific notation below 1e-4;
        # Rust's Display never does. The mantissa digits are the same
        # shortest-roundtrip string, so rewriting to positional form
        # reproduces Rust's bytes exactly.
        r = dec_positional(r)
    return r


def dec_positional(r):
    neg = r.startswith("-")
    if neg:
        r = r[1:]
    mant, _, exp = r.lower().partition("e")
    exp = int(exp)
    if "." in mant:
        ip, fp = mant.split(".")
    else:
        ip, fp = mant, ""
    digits = (ip + fp).lstrip("0") or "0"
    # Decimal point position counted from the left of `digits`.
    lead_zeros = len(ip) - len(ip.lstrip("0"))
    point = len(ip) - lead_zeros + exp
    if digits == "0":
        out = "0"
    elif point <= 0:
        out = "0." + "0" * (-point) + digits
    elif point >= len(digits):
        out = digits + "0" * (point - len(digits))
    else:
        out = digits[:point] + "." + digits[point:]
    return ("-" if neg else "") + out


def mean(xs):
    acc = 0.0
    for x in xs:
        acc += x
    return acc / len(xs)


def percentile(xs, p):
    ys = sorted(xs)
    r = p / 100.0 * (len(ys) - 1)
    lo = math.floor(r)
    hi = math.ceil(r)
    if lo == hi:
        return ys[lo]
    w = r - lo
    return ys[lo] * (1.0 - w) + ys[hi] * w


def row_json(row):
    parts = []
    for k in sorted(row.keys()):
        v = row[k]
        if isinstance(v, str):
            sv = '"' + v + '"'
        elif isinstance(v, bool):
            sv = "true" if v else "false"
        elif isinstance(v, dict):
            sv = row_json(v)
        elif isinstance(v, list):
            if v and isinstance(v[0], dict):
                sv = "[" + ",".join(row_json(x) for x in v) + "]"
            else:
                sv = "[" + ",".join(jnum(x) for x in v) + "]"
        else:
            sv = jnum(v)
        parts.append('"' + k + '":' + sv)
    return "{" + ",".join(parts) + "}"


def report_json(rows, schema=SCHEMA):
    s = "{\n"
    s += '"schema":"' + schema + '",\n'
    s += '"rows":[\n'
    for i, row in enumerate(rows):
        s += row_json(row)
        if i + 1 < len(rows):
            s += ","
        s += "\n"
    s += "]\n}\n"
    return s


def tenant_rows(name, out):
    names = scenario_tenant_names()[name]
    rows = []
    for ti, tname in enumerate(names):
        ls = out["tenant_lat"][ti] if ti < len(out["tenant_lat"]) else []
        ts = out["tenant_ttft"][ti] if ti < len(out["tenant_ttft"]) else []
        if ls:
            rows.append({
                "tenant": tname,
                "n": len(ls),
                "mean_latency_s": mean(ls),
                "p50_latency_s": percentile(ls, 50.0),
                "p99_latency_s": percentile(ls, 99.0),
                "mean_ttft_s": mean(ts),
            })
        else:
            rows.append({
                "tenant": tname,
                "n": 0,
                "mean_latency_s": 0.0,
                "p50_latency_s": 0.0,
                "p99_latency_s": 0.0,
                "mean_ttft_s": 0.0,
            })
    return rows


def slowdown_rows(name, out):
    names = scenario_tenant_names()[name]
    rows = []
    for ti, tname in enumerate(names):
        ls = out["tenant_slow"][ti] if ti < len(out["tenant_slow"]) else []
        if ls:
            rows.append({
                "tenant": tname,
                "n": len(ls),
                "mean_slowdown": mean(ls),
                "p50_slowdown": percentile(ls, 50.0),
                "p99_slowdown": percentile(ls, 99.0),
            })
        else:
            rows.append({
                "tenant": tname,
                "n": 0,
                "mean_slowdown": 0.0,
                "p50_slowdown": 0.0,
                "p99_slowdown": 0.0,
            })
    return rows


def fairness_obj(name, fair, out):
    """FairnessRow::from_outcome — knobs + per-tenant slowdowns, Jain's
    index over per-tenant mean slowdowns, max starvation age."""
    pts = slowdown_rows(name, out)
    s1 = 0.0
    s2 = 0.0
    k = 0
    for row in pts:
        if row["n"] > 0:
            m = row["mean_slowdown"]
            s1 += m
            s2 += m * m
            k += 1
    jain = 1.0 if (k == 0 or s2 <= 0.0) else s1 * s1 / (float(k) * s2)
    return {
        "mode": fair.mode_label(),
        "quantum_s": fair.quantum,
        "aging_boost": fair.boost,
        "max_aging_levels": fair.levels,
        "tenant_weights": list(fair.weights),
        "jain_slowdown": jain,
        "max_starve_age_s": out["max_starve"],
        "per_tenant_slowdown": pts,
    }


def make_row(name, policy, dispatch, replicas, migration, seed, out,
             selector=None, tenant_breakdown=False, fairness=None):
    row = {
        "scenario": name,
        "policy": policy_name(policy),
        "dispatch": {"rr": "round-robin", "jsq": "jsq", "lpw": "least-work",
                     "affinity": "affinity"}[dispatch],
        "replicas": replicas,
        "migration": migration,
        "n": out["n"],
        # u64s travel as strings (golden_fixture.json convention)
        "seed": str(seed),
        "mean_latency_s": mean(out["lat"]),
        "p50_latency_s": percentile(out["lat"], 50.0),
        "p99_latency_s": percentile(out["lat"], 99.0),
        "mean_ttft_s": mean(out["ttft"]),
        "p50_ttft_s": percentile(out["ttft"], 50.0),
        "p99_ttft_s": percentile(out["ttft"], 99.0),
        "throughput_req_s": out["n"] / out["makespan"] if out["makespan"] > 0 else 0.0,
        "makespan_s": out["makespan"],
        "preemptions": out["preemptions"],
        "discards": out["discards"],
        "migrations": out["migrations"],
        "kv_peak_tokens": out["kv_peak"],
        "n_iterations": out["iters"],
        "per_replica_finished": out["per_replica"],
    }
    if selector is not None:
        row["selector"] = selector
        row["selector_ops"] = out["sel_ops"]
    if tenant_breakdown:
        row["per_tenant"] = tenant_rows(name, out)
    if fairness is not None:
        row["fairness"] = fairness_obj(name, fairness, out)
    return row


def sweep_rows(scenario_names, policies, replica_counts, migration, selector="indexed"):
    rows = []
    scs = builtin_scenarios()
    for name in scenario_names:
        tenants, n, seed, dispatch, slots, pool_frac, noise = scs[name]
        trace = generate_trace(tenants, n, seed)
        pool_tokens = int((slots * MAX_SEQ) * pool_frac)
        for replicas in replica_counts:
            for policy in policies:
                out = run_sim(trace, policy, replicas, dispatch, migration, slots,
                              pool_tokens, noise, selector=selector)
                rows.append(make_row(name, policy, dispatch, replicas, migration, seed, out))
    return rows


# (scenario, replicas) grid of the scheduler-scale sweep — keep in sync
# with rust/src/sim/scenario.rs `sched_sweep`.
SCHED_GRID = [("scale-1k", 4), ("scale-10k", 4), ("scale-replicas", 128)]
SCHED_POLICY = ("trail", 0.8)


def sched_rows():
    rows = []
    scs = builtin_scenarios()
    for name, replicas in SCHED_GRID:
        tenants, n, seed, dispatch, slots, pool_frac, noise = scs[name]
        trace = generate_trace(tenants, n, seed)
        pool_tokens = int((slots * MAX_SEQ) * pool_frac)
        for selector in ("reference", "indexed"):
            out = run_sim(trace, SCHED_POLICY, replicas, dispatch, True, slots,
                          pool_tokens, noise, selector=selector)
            rows.append(make_row(name, SCHED_POLICY, dispatch, replicas, True, seed, out,
                                 selector=selector, tenant_breakdown=True))
    return rows


# Fairness sweep (rust/src/sim/scenario.rs run_fair_sweep — keep in
# sync): each fair scenario × fairness mode at 2 replicas, plus
# fair-fleet at 128 replicas × dispatch policy × {off, guard+shares}.
# Guard knobs: boost 512 = 2x the output cap, so one elapsed quantum
# outranks every unlocked key (binary "starved" flag; gentler per-level
# boosts churn the KV cache without bounding the tail sooner).
FAIR_QUANTUM = 0.75
FAIR_FLEET_QUANTUM = 0.25
FAIR_POLICY = ("trail", 0.8)
FAIR_SCENARIOS = ("fair-steady", "fair-skewed", "fair-adversarial")


def fair_modes():
    return [
        FairCfg(),
        FairCfg(FAIR_QUANTUM, 512.0, 2),
        FairCfg(FAIR_QUANTUM, 512.0, 2, (1.0, 1.0)),
    ]


def fair_rows():
    rows = []
    scs = builtin_scenarios()
    for name in FAIR_SCENARIOS:
        tenants, n, seed, dispatch, slots, pool_frac, noise = scs[name]
        trace = generate_trace(tenants, n, seed)
        pool_tokens = int((slots * MAX_SEQ) * pool_frac)
        for fair in fair_modes():
            out = run_sim(trace, FAIR_POLICY, 2, dispatch, True, slots, pool_tokens,
                          noise, fair=fair)
            rows.append(make_row(name, FAIR_POLICY, dispatch, 2, True, seed, out,
                                 tenant_breakdown=True, fairness=fair))
    tenants, n, seed, _, slots, pool_frac, noise = scs["fair-fleet"]
    trace = generate_trace(tenants, n, seed)
    pool_tokens = int((slots * MAX_SEQ) * pool_frac)
    for dispatch in ("rr", "jsq", "lpw"):
        for fair in (FairCfg(), FairCfg(FAIR_FLEET_QUANTUM, 512.0, 2, (1.0, 1.0))):
            out = run_sim(trace, FAIR_POLICY, 128, dispatch, True, slots, pool_tokens,
                          noise, fair=fair)
            rows.append(make_row("fair-fleet", FAIR_POLICY, dispatch, 128, True, seed,
                                 out, tenant_breakdown=True, fairness=fair))
    return rows


# Prefix-cache sweep (rust/src/sim/scenario.rs run_prefix_sweep — keep
# in sync): each prefix scenario kind × sharing factor × dispatch
# (least-work vs affinity) at 2 replicas, dispatch cells paired on the
# identical trace.
PREFIX_SCHEMA = "trail.simlab.prefix/v1"
PREFIX_SHARES = [0.0, 0.5, 0.9]
PREFIX_POLICY = ("trail", 0.8)


def prefix_scenario(kind, share):
    spec = prefix_agentic(share) if kind == "agentic" else prefix_rag(share)
    # (tenants, n, seed, slots, pool_frac, noise) — pool sized so the
    # share-0 baseline saturates it while shared cells run under it
    # (see rust/src/sim/scenario.rs prefix_scenario).
    return ([(60.0, 0.0, [], spec)], 360, 31337, 16, 0.7, 0.4)


def prefix_rows():
    rows = []
    for kind in ("agentic", "rag"):
        for share in PREFIX_SHARES:
            tenants, n, seed, slots, pool_frac, noise = prefix_scenario(kind, share)
            trace = generate_trace(tenants, n, seed)
            pool_tokens = int((slots * MAX_SEQ) * pool_frac)
            for dispatch in ("lpw", "affinity"):
                out = run_sim(trace, PREFIX_POLICY, 2, dispatch, True, slots,
                              pool_tokens, noise, prefix_cache=True)
                row = make_row("prefix-" + kind, PREFIX_POLICY, dispatch, 2, True,
                               seed, out)
                row["prefix"] = {
                    "share_factor": share,
                    "prefix_hits": out["prefix_hits"],
                    "reused_tokens": out["reused_tokens"],
                }
                rows.append(row)
    return rows


# Predictor-arena sweep (rust/src/sim/scenario.rs run_pred_sweep — keep
# in sync): predictor × policy × scenario at 2 replicas. The fcfs rows
# are the predictor-insensitive control — fcfs never reads predictions,
# so its latency is identical across predictors and only the quality
# metrics move; the trail rows show quality mapping to p99.
PRED_SCHEMA = "trail.simlab.pred/v1"
PRED_POLICIES = [("fcfs",), ("trail", 0.8)]
PRED_PREDICTORS = [("probe",), ("bucket",), ("rank",), ("online",)]
PRED_SCENARIOS = ("pred-steady", "pred-drift")


def pred_obj(out):
    """PredRow::from_outcome."""
    tau, inv, mae, n = pred_quality(out["pred_pairs"])
    return {
        "predictor": out["predictor"],
        "kendall_tau": tau,
        "inversion_rate": inv,
        "mae": mae,
        "n_pairs": n,
    }


def pred_rows():
    rows = []
    scs = builtin_scenarios()
    for name in PRED_SCENARIOS:
        tenants, n, seed, dispatch, slots, pool_frac, noise = scs[name]
        trace = generate_trace(tenants, n, seed)
        pool_tokens = int((slots * MAX_SEQ) * pool_frac)
        for policy in PRED_POLICIES:
            for spec in PRED_PREDICTORS:
                out = run_sim(trace, policy, 2, dispatch, True, slots, pool_tokens,
                              noise, predictor=spec)
                row = make_row(name, policy, dispatch, 2, True, seed, out)
                row["pred"] = pred_obj(out)
                rows.append(row)
    return rows


# Flight-recorder sweep (rust/src/sim/scenario.rs run_obs_sweep — keep
# in sync): scale-1k × {fcfs, trail-c0.8} at 2 replicas with tracing
# and the phase timer on, every cell on the identical trace. The pinned
# bytes are pure virtual-time data: event counts by kind, the trace FNV
# fingerprint, phase calls + virtual totals, p99 tails. Wall-clock
# spans feed `--timings-json` only and never enter the report.
OBS_SCHEMA = "trail.simlab.obs/v1"
OBS_POLICIES = [("fcfs",), ("trail", 0.8)]


def obs_obj(out, trace_text):
    """ObsRow::from_outcome — event histogram, trace fingerprint, and
    the hot-loop phase table for one traced cell."""
    by_kind = {}
    for ev in out["trace_events"]:
        by_kind[ev[4]] = by_kind.get(ev[4], 0) + 1
    return {
        "events": by_kind,
        "n_events": len(out["trace_events"]),
        "p99_latency_s": percentile(out["lat"], 99.0),
        "p99_ttft_s": percentile(out["ttft"], 99.0),
        "phases": [
            {"name": name, "calls": calls, "virtual_s": virtual_s}
            for name, calls, virtual_s in phase_rows(out["phase_counts"])
        ],
        "trace_fnv": "%016x" % fnv1a64(trace_text.encode()),
    }


def obs_rows():
    """Returns (rows, traces, phase_counts, timing): the report rows
    plus the artifacts behind them — per-cell rendered trace texts in
    grid order, merged phase counts, and merged wall spans."""
    scs = builtin_scenarios()
    tenants, n, seed, dispatch, slots, pool_frac, noise = scs["scale-1k"]
    trace = generate_trace(tenants, n, seed)
    pool_tokens = int((slots * MAX_SEQ) * pool_frac)
    rows = []
    traces = []
    counts = new_phase_counts()
    timing = None
    for policy in OBS_POLICIES:
        out = run_sim(trace, policy, 2, dispatch, True, slots, pool_tokens,
                      noise, obs=(True, True))
        cell = "scale-1k/" + policy_name(policy) + "/r2"
        text = render_trace(out["trace_events"], cell=cell)
        merge_phase_counts(counts, out["phase_counts"])
        if out["timing"] is not None:
            if timing is None:
                timing = out["timing"]
            else:
                timing.merge(out["timing"])
        row = make_row("scale-1k", policy, dispatch, 2, True, seed, out)
        row["obs"] = obs_obj(out, text)
        rows.append(row)
        traces.append((cell, text))
    return rows, traces, counts, timing


# Scale sweep (rust/src/sim/scenario.rs run_scale_sweep — keep in
# sync): each scale scenario × worker count at 8 replicas under TRAIL
# c=0.8, migration off, phase counters on. Every pinned field except
# `scale.workers` is worker-invariant — the Rust parallel driver is
# byte-identical to its serial loop, which this mirror *is* — so one
# serial run per scenario regenerates all four worker rows.
SCALE_SCHEMA = "trail.simlab.scale/v1"
SCALE_WORKERS = [1, 2, 4, 8]
SCALE_REPLICAS = 8
SCALE_SCENARIOS = ("scale-10k", "scale-100k")
SCALE_POLICY = ("trail", 0.8)


def scale_obj(out, workers):
    """ScaleRow::from_outcome — the worker count plus the phase table."""
    return {
        "workers": workers,
        "phases": [
            {"name": name, "calls": calls, "virtual_s": virtual_s}
            for name, calls, virtual_s in phase_rows(out["phase_counts"])
        ],
    }


def scale_rows(scenario_names=SCALE_SCENARIOS):
    rows = []
    scs = builtin_scenarios()
    for name in scenario_names:
        tenants, n, seed, dispatch, slots, pool_frac, noise = scs[name]
        trace = generate_trace(tenants, n, seed)
        pool_tokens = int((slots * MAX_SEQ) * pool_frac)
        out = run_sim(trace, SCALE_POLICY, SCALE_REPLICAS, dispatch, False, slots,
                      pool_tokens, noise, obs=(False, True))
        for w in SCALE_WORKERS:
            row = make_row(name, SCALE_POLICY, dispatch, SCALE_REPLICAS, False,
                           seed, out)
            row["scale"] = scale_obj(out, w)
            rows.append(row)
    return rows


# Fleet chaos sweep (rust/src/sim/scenario.rs run_fleet_sweep — keep in
# sync): each fleet scenario × failure rate {0, FLEET_FAILURE_RATE} ×
# autoscaler {off, on} at FLEET_REPLICAS replicas under TRAIL c=0.8,
# every cell of a scenario on the identical trace (and the failure
# cells on the identical crash schedule), so the autoscaler-on vs -off
# comparison is paired. Migration stays off — fleet dynamics owns
# request movement.
FLEET_SCHEMA = "trail.simlab.fleet/v1"
FLEET_REPLICAS = 6
FLEET_FAILURE_RATE = 0.4
FLEET_SCENARIOS = ("fleet-steady", "fleet-diurnal", "fleet-flash")
FLEET_POLICY = ("trail", 0.8)


def chaos_fleet():
    """scenario.rs chaos_fleet — the chaos grid's fleet regime: crash
    recovery in 2 s, redispatch on, a backlog autoscaler over 4..=6
    replicas with a 0.75 s boot, 50 ms-stale dispatch snapshots,
    batch-class admission control, and two slow-generation spares. The
    sweep flips failure_rate and autoscaler per cell."""
    fl = default_fleet()
    fl.update({
        "seed": 1337,
        "failure_rate": 0.0,
        "horizon_s": 30.0,
        "recovery_s": 2.0,
        "redispatch": True,
        "autoscaler": False,
        "min_replicas": 3,
        "max_replicas": 0,
        "initial_up": 4,
        "boot_delay_s": 0.75,
        "check_interval_s": 0.25,
        "up_backlog": 6.0,
        "down_backlog": 1.0,
        "stale_s": 0.05,
        "slo_classes": [0, 1],
        "shed_queue": 48,
        "degrade_queue": 32,
        "degrade_cap": 24,
        "cost_mults": [1.0, 1.0, 1.0, 1.0, 1.35, 1.35],
    })
    return fl


def fleet_rows():
    rows = []
    scs = builtin_scenarios()
    for name in FLEET_SCENARIOS:
        tenants, n, seed, dispatch, slots, pool_frac, noise = scs[name]
        trace = generate_trace(tenants, n, seed)
        pool_tokens = int((slots * MAX_SEQ) * pool_frac)
        for failure_rate in (0.0, FLEET_FAILURE_RATE):
            for autoscaler in (False, True):
                fl = chaos_fleet()
                fl["failure_rate"] = failure_rate
                fl["autoscaler"] = autoscaler
                out = run_fleet_sim(trace, FLEET_POLICY, FLEET_REPLICAS, dispatch,
                                    slots, pool_tokens, fl, noise)
                row = make_row(name, FLEET_POLICY, dispatch, FLEET_REPLICAS, False,
                               seed, out, tenant_breakdown=True)
                row["fleet"] = out["fleet"]
                rows.append(row)
    return rows


DEFAULT_POLICIES = [("fcfs",), ("trail", 1.0), ("trail", 0.8)]


def main(argv):
    if not argv or argv[0] not in ("sweep", "sched", "fair", "prefix", "pred", "obs",
                                   "scale", "fleet"):
        print(__doc__)
        return 2
    out_path = None
    if "--out" in argv:
        out_path = argv[argv.index("--out") + 1]
    if argv[0] == "obs":
        rows, traces, counts, timing = obs_rows()
        text = report_json(rows, schema=OBS_SCHEMA)
        for row in rows:
            ob = row["obs"]
            print(
                f"{row['scenario']:>10} {row['policy']:>10} x{row['replicas']} "
                f"events={ob['n_events']} fnv={ob['trace_fnv']} "
                f"p99={ob['p99_latency_s']:.3f}s discard={row['discards']}"
            )
        if timing is not None:
            print(
                f"timer overhead: {timing.overhead_frac() * 100.0:.2f}% of "
                f"{timing.total_wall_s():.4f}s step wall time ({timing.n_spans} spans)"
            )
        if "--trace-jsonl" in argv:
            tj = argv[argv.index("--trace-jsonl") + 1]
            with open(tj, "w") as f:
                f.write("".join(t for _, t in traces))
            print(f"trace events ({len(traces)} cells) -> {tj}")
        if "--timings-json" in argv:
            tp = argv[argv.index("--timings-json") + 1]
            with open(tp, "w") as f:
                f.write(timing_report_text(counts, timing))
            print(f"phase timings -> {tp}")
    elif argv[0] == "scale":
        names = SCALE_SCENARIOS
        if "--scenarios" in argv:
            names = tuple(
                s for s in argv[argv.index("--scenarios") + 1].split(",") if s
            )
        rows = scale_rows(names)
        text = report_json(rows, schema=SCALE_SCHEMA)
        for row in rows:
            sr = row["scale"]
            print(
                f"{row['scenario']:>12} workers={sr['workers']} x{row['replicas']} "
                f"n={row['n']} mean={row['mean_latency_s']:.3f}s "
                f"p99={row['p99_latency_s']:.3f}s req/s={row['throughput_req_s']:.2f} "
                f"discard={row['discards']}"
            )
    elif argv[0] == "fleet":
        rows = fleet_rows()
        text = report_json(rows, schema=FLEET_SCHEMA)
        for row in rows:
            fr = row["fleet"]
            scaler = "on" if fr["autoscaler"] else "off"
            print(
                f"{row['scenario']:>14} fail={fr['failure_rate']:.2f} "
                f"scaler={scaler:>3} crash={fr['crashes']} lost={fr['lost']} "
                f"shed={fr['shed']} up={fr['up_min']}-{fr['up_max']} "
                f"int_p99={fr['interactive_p99_s']:.3f}s "
                f"bat_p99={fr['batch_p99_s']:.3f}s discard={row['discards']}"
            )
    elif argv[0] == "pred":
        rows = pred_rows()
        text = report_json(rows, schema=PRED_SCHEMA)
        for row in rows:
            pr = row["pred"]
            print(
                f"{row['scenario']:>12} {row['policy']:>10} {pr['predictor']:>7} "
                f"mean={row['mean_latency_s']:.3f}s p99={row['p99_latency_s']:.3f}s "
                f"tau={pr['kendall_tau']:.3f} inv={pr['inversion_rate']:.3f} "
                f"mae={pr['mae']:.1f} discard={row['discards']}"
            )
    elif argv[0] == "prefix":
        rows = prefix_rows()
        text = report_json(rows, schema=PREFIX_SCHEMA)
        for row in rows:
            pr = row["prefix"]
            print(
                f"{row['scenario']:>14} share={pr['share_factor']:.1f} "
                f"{row['dispatch']:>10} ttft={row['mean_ttft_s']:.3f}s "
                f"kv_peak={row['kv_peak_tokens']} hits={pr['prefix_hits']} "
                f"reused={pr['reused_tokens']} discard={row['discards']}"
            )
    elif argv[0] == "fair":
        rows = fair_rows()
        text = report_json(rows, schema=FAIR_SCHEMA)
        for row in rows:
            fr = row["fairness"]
            print(
                f"{row['scenario']:>16} {fr['mode']:>12} {row['dispatch']:>11} "
                f"x{row['replicas']} mean={row['mean_latency_s']:.3f}s "
                f"p99={row['p99_latency_s']:.3f}s jain={fr['jain_slowdown']:.3f} "
                f"starve={fr['max_starve_age_s']:.3f}s discard={row['discards']}"
            )
    elif argv[0] == "sched":
        rows = sched_rows()
        text = report_json(rows, schema=SCHED_SCHEMA)
        for row in rows:
            print(
                f"{row['scenario']:>14} {row['selector']:>9} x{row['replicas']} "
                f"n={row['n']} ops={row['selector_ops']} iters={row['n_iterations']} "
                f"mean={row['mean_latency_s']:.3f}s discard={row['discards']}"
            )
    else:
        selector = "indexed"
        if "--selector" in argv:
            selector = argv[argv.index("--selector") + 1]
        rows = sweep_rows(
            ["steady", "bursty", "multi-tenant", "skewed"],
            DEFAULT_POLICIES,
            [2, 4],
            migration=True,
            selector=selector,
        )
        text = report_json(rows)
        for row in rows:
            print(
                f"{row['scenario']:>13} {row['policy']:>10} x{row['replicas']} "
                f"mean={row['mean_latency_s']:.3f}s p99={row['p99_latency_s']:.3f}s "
                f"ttft={row['mean_ttft_s']:.3f}s preempt={row['preemptions']} "
                f"discard={row['discards']} migrate={row['migrations']}"
            )
    if out_path:
        with open(out_path, "w") as f:
            f.write(text)
        print(f"wrote {out_path} ({len(rows)} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
