"""Probe profiling + training pipeline (small smoke-scale run)."""

import numpy as np
import pytest

from compile import model as M
from compile import probe as P
from compile.config import BINS
from compile.workload import gen_requests


@pytest.fixture(scope="module")
def params():
    return M.init_params()


@pytest.fixture(scope="module")
def data(params):
    return P.profile_requests(params, gen_requests(24, 555))


def test_profile_shapes_and_labels(data):
    n = len(data.decode_y)
    assert data.decode_x.shape == (n, 9, 64)
    assert data.decode_rem.shape == (n,)
    assert (data.decode_y >= 0).all() and (data.decode_y < BINS.n_bins).all()
    # Labels are consistent with bins.
    for i in range(0, n, 97):
        assert data.decode_y[i] == BINS.bin_of(data.decode_rem[i])
    # Per-request iteration counts equal the true output length.
    reqs = gen_requests(24, 555)
    for r in reqs:
        assert int((data.decode_req == r.rid).sum()) == r.true_output_len


def test_profile_remaining_decreases_within_request(data):
    rid = data.decode_req[0]
    mask = data.decode_req == rid
    ts = data.decode_t[mask]
    rems = data.decode_rem[mask]
    order = np.argsort(ts)
    assert (np.diff(rems[order]) == -1).all()


def test_training_learns_signal(params, data):
    # A quickly-trained probe must beat the uniform-guess MAE.
    probes = P.train_probe(data.decode_x, data.decode_y, steps=300)
    tap = 4
    probs = P.probe_predict(
        {k: np.asarray(v[tap]) for k, v in probes.items()}, data.decode_x[:, tap, :])
    pred = P.expected_length(probs)
    mae = np.abs(pred - data.decode_rem).mean()
    uniform = np.abs(np.mean(BINS.midpoints) - data.decode_rem).mean()
    assert mae < uniform, f"probe MAE {mae} !< uniform {uniform}"


def test_probe_predict_is_distribution(params, data):
    probes = P.train_probe(data.decode_x[:500], data.decode_y[:500], steps=50)
    p = P.probe_predict(
        {k: np.asarray(v[0]) for k, v in probes.items()}, data.decode_x[:32, 0, :])
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)
