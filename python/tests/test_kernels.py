"""L1 correctness: Pallas kernels vs pure-jnp oracles, with hypothesis
sweeping shapes (the spec's L1 test requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, mlp, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 5),
    h=st.integers(1, 4),
    s=st.sampled_from([16, 48, 64, 96, 160]),
    dh=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_decode_attention_matches_ref(b, h, s, dh, seed):
    q = rand(seed, (b, h, dh))
    k = rand(seed + 1, (b, h, s, dh))
    v = rand(seed + 2, (b, h, s, dh))
    lens_np = np.random.default_rng(seed).integers(0, s + 1, size=b)
    lens = jnp.asarray(lens_np, jnp.int32)
    out = attention.decode_attention(q, k, v, lens)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


def test_decode_attention_inactive_slot_zero():
    q = rand(0, (2, 4, 16))
    k = rand(1, (2, 4, 64, 16))
    v = rand(2, (2, 4, 64, 16))
    lens = jnp.asarray([0, 64], jnp.int32)
    out = attention.decode_attention(q, k, v, lens)
    np.testing.assert_allclose(out[0], jnp.zeros_like(out[0]), atol=1e-7)


def test_decode_attention_single_valid_key_returns_value():
    # With one valid key, softmax weight is 1: output == v at that key.
    q = rand(3, (1, 2, 8))
    k = rand(4, (1, 2, 32, 8))
    v = rand(5, (1, 2, 32, 8))
    lens = jnp.asarray([1], jnp.int32)
    out = attention.decode_attention(q, k, v, lens)
    np.testing.assert_allclose(out[0], v[0, :, 0, :], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("seq_tile", [16, 32, 64, 128])
def test_decode_attention_tile_invariance(seq_tile):
    # The online-softmax result must not depend on the VMEM tile size.
    q = rand(7, (3, 4, 16))
    k = rand(8, (3, 4, 96, 16))
    v = rand(9, (3, 4, 96, 16))
    lens = jnp.asarray([96, 40, 1], jnp.int32)
    out = attention.decode_attention(q, k, v, lens, seq_tile=seq_tile)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Prefill attention
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(1, 16),
    h=st.integers(1, 4),
    s=st.sampled_from([32, 64, 96]),
    dh=st.sampled_from([8, 16]),
    start=st.integers(0, 10),
    seed=st.integers(0, 2**16),
)
def test_prefill_attention_matches_ref(c, h, s, dh, start, seed):
    if start + c > s:
        start = s - c
    q = rand(seed, (c, h, dh))
    k = rand(seed + 1, (h, s, dh))
    v = rand(seed + 2, (h, s, dh))
    q_pos = jnp.arange(start, start + c, dtype=jnp.int32)
    lens = jnp.asarray(start + c, jnp.int32)
    out = attention.prefill_attention(q, k, v, q_pos, lens)
    want = ref.prefill_attention_ref(q, k, v, q_pos, start + c)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


def test_prefill_attention_causality():
    # Changing a future key must not change earlier queries' outputs.
    h, s, dh, c = 2, 32, 8, 4
    q = rand(1, (c, h, dh))
    k = rand(2, (h, s, dh))
    v = rand(3, (h, s, dh))
    q_pos = jnp.arange(0, c, dtype=jnp.int32)
    out1 = attention.prefill_attention(q, k, v, q_pos, jnp.asarray(c, jnp.int32))
    k2 = k.at[:, c - 1, :].set(99.0)  # key visible only to the last query
    v2 = v.at[:, c - 1, :].set(-99.0)
    out2 = attention.prefill_attention(q, k2, v2, q_pos, jnp.asarray(c, jnp.int32))
    np.testing.assert_allclose(out1[: c - 1], out2[: c - 1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(out1[c - 1], out2[c - 1])


# ---------------------------------------------------------------------------
# Predictor MLP
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([1, 3, 8, 100, 128, 200]),
    d=st.sampled_from([16, 64]),
    hd=st.sampled_from([32, 64]),
    k=st.sampled_from([5, 10]),
    seed=st.integers(0, 2**16),
)
def test_predictor_mlp_matches_ref(n, d, hd, k, seed):
    x = rand(seed, (n, d))
    w1 = rand(seed + 1, (d, hd), 0.2)
    b1 = rand(seed + 2, (hd,), 0.1)
    w2 = rand(seed + 3, (hd, k), 0.2)
    b2 = rand(seed + 4, (k,), 0.1)
    out = mlp.predictor_mlp(x, w1, b1, w2, b2)
    want = ref.predictor_mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


def test_predictor_mlp_rows_are_distributions():
    x = rand(11, (32, 64))
    w1 = rand(12, (64, 64), 0.2)
    out = mlp.predictor_mlp(x, w1, jnp.zeros(64), rand(13, (64, 10), 0.2), jnp.zeros(10))
    np.testing.assert_allclose(np.asarray(out).sum(-1), np.ones(32), rtol=1e-5)
    assert (np.asarray(out) >= 0).all()


@pytest.mark.parametrize("batch_tile", [8, 32, 128])
def test_predictor_mlp_tile_invariance(batch_tile):
    x = rand(21, (100, 64))
    w1 = rand(22, (64, 64), 0.2)
    b1 = jnp.zeros(64)
    w2 = rand(23, (64, 10), 0.2)
    b2 = jnp.zeros(10)
    out = mlp.predictor_mlp(x, w1, b1, w2, b2, batch_tile=batch_tile)
    want = ref.predictor_mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)
