"""Bayesian smoothing (paper Appendix A) — structural properties and the
behaviours Fig 3 depends on."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.config import BINS
from compile.smoothing import BayesianSmoother, smooth_sequence, transition_matrix


def test_transition_matrix_structure():
    t = transition_matrix()
    k = BINS.n_bins
    assert t.shape == (k, k)
    for i in range(k):
        assert abs(t[i, i] - (1 - 1 / BINS.width)) < 1e-12
        if i + 1 < k:
            assert abs(t[i, i + 1] - 1 / BINS.width) < 1e-12
    # Lower-bidiagonal: nothing else non-zero.
    mask = np.ones_like(t, dtype=bool)
    for i in range(k):
        mask[i, i] = False
        if i + 1 < k:
            mask[i, i + 1] = False
    assert np.all(t[mask] == 0)


@given(st.lists(st.floats(0.01, 1.0), min_size=BINS.n_bins, max_size=BINS.n_bins))
@settings(max_examples=50, deadline=None)
def test_update_stays_on_simplex(p):
    sm = BayesianSmoother()
    sm.reset(np.ones(BINS.n_bins) / BINS.n_bins)
    sm.update(np.asarray(p))
    assert abs(sm.q.sum() - 1.0) < 1e-9
    assert (sm.q >= 0).all()


def test_drift_lowers_expected_remaining():
    sm = BayesianSmoother()
    p0 = np.zeros(BINS.n_bins)
    p0[-1] = 1.0
    sm.reset(p0)
    start = sm.predicted_length()
    flat = np.ones(BINS.n_bins) / BINS.n_bins
    for _ in range(60):
        sm.update(flat)
    assert sm.predicted_length() < start - 20


def test_smoothing_reduces_noise_mae():
    # The Fig 3 mechanism: a noisy classifier around the true (drifting)
    # bin is improved by refinement.
    rng = np.random.default_rng(0)
    k = BINS.n_bins
    n = 200
    true_total = 220.0
    raw_err, ref_err = [], []
    p_seq = []
    for t in range(n):
        remaining = true_total - t
        true_bin = BINS.bin_of(max(remaining, 0))
        p = np.full(k, 0.03)
        p[true_bin] += 0.5
        noise_bin = rng.integers(0, k)
        p[noise_bin] += 0.6 * rng.random()
        p /= p.sum()
        p_seq.append(p)
        raw_err.append(abs(p @ np.asarray(BINS.midpoints) - max(remaining, 0)))
    preds = smooth_sequence(np.asarray(p_seq))
    for t in range(n):
        ref_err.append(abs(preds[t] - max(true_total - t, 0)))
    assert np.mean(ref_err) < np.mean(raw_err)


def test_nonfinite_classifier_recovers():
    # Regression: a NaN classifier row used to poison q — the NaN sum
    # fails `s <= 1e-30`, so the degenerate-disagreement fallback never
    # fired. Mirrors rust smoothing.rs `nan_classifier_row_recovers`.
    sm = BayesianSmoother()
    sm.reset(np.ones(BINS.n_bins) / BINS.n_bins)
    p = np.full(BINS.n_bins, 0.1)
    p[4] = np.nan
    sm.update(p)
    assert np.isfinite(sm.q).all()
    assert abs(sm.q.sum() - 1.0) < 1e-9
    # A non-finite reset row falls back to uniform the same way.
    sm.reset(p)
    assert np.allclose(sm.q, 1.0 / BINS.n_bins)


def test_degenerate_disagreement_recovers():
    sm = BayesianSmoother()
    q0 = np.zeros(BINS.n_bins)
    q0[-1] = 1.0
    sm.reset(q0)
    p = np.zeros(BINS.n_bins)
    p[0] = 1.0
    sm.update(p)
    assert np.isfinite(sm.q).all()
    assert abs(sm.q.sum() - 1.0) < 1e-9
