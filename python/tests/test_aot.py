"""AOT artifact smoke: config/layout consistency and HLO text structure."""

import json
import os

import pytest

from compile.config import BINS, LAYOUT, MODEL, config_dict

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_layout_tiles_exactly():
    lay = LAYOUT
    assert lay.kv_off == 0
    assert lay.logits_off == lay.kv_len
    assert lay.total == lay.pcnt_off + lay.pcnt_len
    assert lay.kv_len == MODEL.kv_elems
    assert lay.taps_len == MODEL.n_taps * MODEL.batch_slots * MODEL.d_model


def test_bins_cover_output_range():
    assert BINS.bin_of(0) == 0
    assert BINS.bin_of(BINS.max_len - 1) == BINS.n_bins - 1
    assert BINS.bin_of(10 * BINS.max_len) == BINS.n_bins - 1
    mids = BINS.midpoints
    assert all(mids[i] < mids[i + 1] for i in range(len(mids) - 1))


def test_config_dict_serialisable():
    s = json.dumps(config_dict())
    back = json.loads(s)
    assert back["layout"]["total"] == LAYOUT.total


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "config.json")),
                    reason="run `make artifacts` first")
def test_artifacts_exist_and_hlo_is_parseable_text():
    cfg = json.load(open(os.path.join(ART, "config.json")))
    names = cfg["artifacts"]
    for key in ("step", "prefill", "readout"):
        path = os.path.join(ART, names[key])
        assert os.path.exists(path), path
        head = open(path).read(4096)
        assert head.startswith("HloModule"), f"{path} is not HLO text"
        assert "ENTRY" in open(path).read()
    # No elided constants (would break the Rust text parser round-trip).
    step = open(os.path.join(ART, names["step"])).read()
    assert "constant({...})" not in step


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "probe_weights.json")),
                    reason="run `make artifacts` first")
def test_probe_weights_complete():
    w = json.load(open(os.path.join(ART, "probe_weights.json")))
    assert len(w["layers"]) == MODEL.n_layers + 1
    assert len(w["embed"]) == MODEL.vocab * MODEL.d_model
    d, h, k = MODEL.d_model, w["hidden"], BINS.n_bins
    for layer in w["layers"]:
        assert len(layer["w1"]) == d * h
        assert len(layer["w2"]) == h * k
    assert 0 <= w["best_layer"] <= MODEL.n_layers
