"""Workload generator: distribution properties + the golden vectors the
Rust mirror is tested against."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import BINS, MODEL, WORKLOAD
from compile.prng import SplitMix64, erfinv, normal_from_uniform
from compile.workload import (
    Request,
    gen_requests,
    golden_vectors,
    response_token,
    sample_output_len,
)


def test_splitmix_determinism():
    a = SplitMix64(42)
    b = SplitMix64(42)
    assert [a.next_u64() for _ in range(16)] == [b.next_u64() for _ in range(16)]


def test_splitmix_f64_unit_interval():
    r = SplitMix64(7)
    xs = [r.next_f64() for _ in range(5000)]
    assert all(0.0 <= x < 1.0 for x in xs)
    assert abs(np.mean(xs) - 0.5) < 0.02


@given(st.integers(0, 2**64 - 1))
@settings(max_examples=50, deadline=None)
def test_splitmix_matches_reference_mixer(seed):
    # next_u64 must be the standard SplitMix64 finalizer output.
    r = SplitMix64(seed)
    got = r.next_u64()
    s = (seed + 0x9E3779B97F4A7C15) % 2**64
    z = s
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) % 2**64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) % 2**64
    assert got == (z ^ (z >> 31)) % 2**64


def test_erfinv_accuracy():
    for x in [-0.9, -0.3, 0.0, 0.4, 0.85]:
        y = erfinv(x)
        assert abs(math.erf(y) - x) < 5e-3


def test_output_lengths_bounded_and_skewed():
    rng = SplitMix64(1)
    lens = [sample_output_len(rng) for _ in range(5000)]
    assert min(lens) >= WORKLOAD.min_output
    assert max(lens) <= WORKLOAD.max_output
    assert np.mean(lens) > np.median(lens)  # heavy right tail


def test_requests_structure():
    reqs = gen_requests(100, 5)
    for r in reqs:
        assert r.prompt[0] == MODEL.bos_id
        assert WORKLOAD.min_prompt <= len(r.prompt) <= WORKLOAD.max_prompt
        assert len(r.response) == r.true_output_len - 1
        assert all(MODEL.first_content_id <= t < MODEL.vocab for t in r.response)
        assert all(0 <= t < MODEL.vocab for t in r.prompt)


def test_response_tokens_encode_progress():
    # With noise off, the response token is a deterministic function of
    # the remaining-length bucket.
    rng = SplitMix64(3)

    class NoNoise:
        resp_noise_p = 0.0
        resp_bucket = WORKLOAD.resp_bucket

    t_small = response_token(rng, 5, MODEL, NoNoise)
    t_big = response_token(rng, 200, MODEL, NoNoise)
    assert t_big > t_small


def test_disjoint_seeds_disjoint_requests():
    a = gen_requests(50, WORKLOAD.train_seed)
    b = gen_requests(50, WORKLOAD.serve_seed)
    assert any(x.prompt != y.prompt for x, y in zip(a, b))


def test_golden_vectors_stable():
    g1 = golden_vectors()
    g2 = golden_vectors()
    assert g1 == g2
    assert len(g1["requests_seed12345"]) == 4
    # u64 goldens round-trip through their string encoding.
    for s in g1["splitmix_seed42_u64"]:
        assert int(s) < 2**64


def test_generation_is_prefix_stable():
    # Generating N requests then N+k must agree on the first N.
    a = gen_requests(10, 77)
    b = gen_requests(15, 77)
    for x, y in zip(a, b[:10]):
        assert x.prompt == y.prompt
        assert x.true_output_len == y.true_output_len
        assert x.response == y.response


def test_class_signal_monotone_in_prompt():
    # Mean content-token id grows with the observed class (probe signal).
    reqs = gen_requests(3000, 13)
    by_class = {}
    for r in reqs:
        cls = BINS.bin_of(r.true_output_len)
        m = np.mean(r.prompt[1:])
        by_class.setdefault(cls, []).append(m)
    keys = sorted(by_class)
    lo = np.mean(by_class[keys[0]])
    hi = np.mean(by_class[keys[-1]])
    assert hi > lo + 15.0
