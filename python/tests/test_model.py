"""L2 correctness: the packed-state step machine (the graphs the Rust
runtime executes) against the pure-jnp full-forward oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.config import LAYOUT as lay
from compile.config import MODEL as cfg

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    return M.init_params()


@pytest.fixture(scope="module")
def graphs(params):
    return {
        "step": jax.jit(M.make_decode_step(params, use_pallas=False)),
        "step_pallas": jax.jit(M.make_decode_step(params, use_pallas=True)),
        "chunk": jax.jit(M.make_prefill_chunk(params, use_pallas=False)),
        "chunk_pallas": jax.jit(M.make_prefill_chunk(params, use_pallas=True)),
        "readout": jax.jit(M.make_readout()),
        "reset": jax.jit(M.make_slot_reset()),
    }


def prefill(graphs, state, slot, tokens, which="chunk"):
    c = cfg.prefill_chunk
    for start in range(0, len(tokens), c):
        nv = min(c, len(tokens) - start)
        padded = jnp.zeros((c,), jnp.int32).at[:nv].set(
            jnp.asarray(tokens[start:start + nv], jnp.int32))
        state = graphs[which](state, padded, slot, start, nv)
    return state


def test_param_count_matches_formula(params):
    n = sum(int(np.prod(v.shape)) for v in params.values())
    assert n == M.param_count()


def test_prefill_matches_full_forward(graphs, params):
    prompt = [(i * 11) % 240 + 8 for i in range(23)]
    state = jnp.zeros((lay.total,), jnp.float32)
    state = prefill(graphs, state, 0, prompt)
    logits, taps, ptaps, nxt = graphs["readout"](state)
    hid, flog = M.full_forward(params, jnp.asarray(prompt)[None])
    np.testing.assert_allclose(logits[0], flog[0, -1], rtol=1e-4, atol=1e-4)
    # Decode taps = last prompt token's hiddens at every tap point.
    np.testing.assert_allclose(
        taps[:, 0, :], hid[0, -1], rtol=1e-4, atol=1e-4)
    # Prompt taps = mean over prompt positions per layer.
    np.testing.assert_allclose(
        ptaps[:, 0, :], hid[0].mean(axis=0), rtol=1e-4, atol=1e-4)


def test_decode_steps_match_full_forward(graphs, params):
    prompt = [(i * 7) % 240 + 8 for i in range(12)]
    cont = [50, 99, 134, 8, 247]
    state = jnp.zeros((lay.total,), jnp.float32)
    state = prefill(graphs, state, 3, prompt)
    seq = list(prompt)
    for j, tok in enumerate(cont):
        seq.append(tok)
        tokens = jnp.zeros((cfg.batch_slots,), jnp.int32).at[3].set(tok)
        pos = jnp.zeros((cfg.batch_slots,), jnp.int32).at[3].set(len(seq) - 1)
        active = jnp.zeros((cfg.batch_slots,), jnp.float32).at[3].set(1.0)
        state = graphs["step"](state, tokens, pos, active)
        logits, taps, _, _ = graphs["readout"](state)
        hid, flog = M.full_forward(params, jnp.asarray(seq)[None])
        np.testing.assert_allclose(logits[3], flog[0, -1], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(taps[:, 3, :], hid[0, -1], rtol=1e-4, atol=1e-4)


def test_pallas_and_ref_graphs_agree(graphs):
    prompt = [(i * 13) % 240 + 8 for i in range(20)]
    s_ref = prefill(graphs, jnp.zeros((lay.total,), jnp.float32), 0, prompt, "chunk")
    s_pal = prefill(graphs, jnp.zeros((lay.total,), jnp.float32), 0, prompt,
                    "chunk_pallas")
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_pal),
                               rtol=2e-4, atol=2e-4)
    tokens = jnp.full((cfg.batch_slots,), 33, jnp.int32)
    pos = jnp.full((cfg.batch_slots,), len(prompt), jnp.int32)
    active = jnp.zeros((cfg.batch_slots,), jnp.float32).at[0].set(1.0)
    o_ref = graphs["step"](s_ref, tokens, pos, active)
    o_pal = graphs["step_pallas"](s_pal, tokens, pos, active)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                               rtol=2e-4, atol=2e-4)


def test_two_slots_are_independent(graphs):
    # Prefilling slot 1 must not change slot 0's state regions.
    p0 = [(i * 3) % 240 + 8 for i in range(10)]
    p1 = [(i * 17) % 240 + 8 for i in range(14)]
    s_a = prefill(graphs, jnp.zeros((lay.total,), jnp.float32), 0, p0)
    s_ab = prefill(graphs, s_a, 1, p1)
    ro_a = graphs["readout"](s_a)
    ro_ab = graphs["readout"](s_ab)
    np.testing.assert_allclose(ro_a[0][0], ro_ab[0][0], atol=1e-6)  # logits s0
    np.testing.assert_allclose(ro_a[1][:, 0], ro_ab[1][:, 0], atol=1e-6)
    # And slot 1's logits differ from zero-state garbage.
    assert not np.allclose(ro_a[0][1], ro_ab[0][1])


def test_inactive_slots_keep_logits(graphs):
    p0 = [(i * 3) % 240 + 8 for i in range(10)]
    state = prefill(graphs, jnp.zeros((lay.total,), jnp.float32), 1, p0)
    before = graphs["readout"](state)
    tokens = jnp.zeros((cfg.batch_slots,), jnp.int32).at[0].set(42)
    pos = jnp.zeros((cfg.batch_slots,), jnp.int32)
    active = jnp.zeros((cfg.batch_slots,), jnp.float32).at[0].set(1.0)
    state = graphs["step"](state, tokens, pos, active)
    after = graphs["readout"](state)
    np.testing.assert_allclose(before[0][1], after[0][1], atol=1e-6)
    np.testing.assert_allclose(before[1][:, 1], after[1][:, 1], atol=1e-6)


def test_slot_reset_clears_prompt_taps(graphs):
    p0 = [(i * 3) % 240 + 8 for i in range(10)]
    state = prefill(graphs, jnp.zeros((lay.total,), jnp.float32), 2, p0)
    _, _, ptaps, _ = graphs["readout"](state)
    assert np.abs(np.asarray(ptaps[:, 2])).max() > 0
    state = graphs["reset"](state, 2)
    _, _, ptaps2, _ = graphs["readout"](state)
    np.testing.assert_allclose(np.asarray(ptaps2[:, 2]), 0.0, atol=1e-7)


def test_slot_reuse_after_reset_is_clean(graphs, params):
    # Serve a prompt in slot 0, reset, serve a different prompt — results
    # must equal a fresh-state run (length masking hides stale KV).
    p_old = [(i * 5) % 240 + 8 for i in range(30)]
    p_new = [(i * 7) % 240 + 8 for i in range(9)]
    state = prefill(graphs, jnp.zeros((lay.total,), jnp.float32), 0, p_old)
    state = graphs["reset"](state, 0)
    state = prefill(graphs, state, 0, p_new)
    reused = graphs["readout"](state)
    fresh = graphs["readout"](
        prefill(graphs, jnp.zeros((lay.total,), jnp.float32), 0, p_new))
    np.testing.assert_allclose(reused[0][0], fresh[0][0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ptap_slot(reused[2], 0), ptap_slot(fresh[2], 0),
                               rtol=1e-4, atol=1e-4)


def ptap_slot(ptaps, slot):
    return np.asarray(ptaps[:, slot, :])


def test_rope_position_sensitivity(params):
    # The same token at different positions must produce different K.
    x = jnp.ones((1, cfg.n_heads, cfg.d_head))
    r0 = M.rope(x, jnp.asarray([0]))
    r5 = M.rope(x, jnp.asarray([5]))
    assert not np.allclose(np.asarray(r0), np.asarray(r5))
    # Position 0 is the identity rotation.
    np.testing.assert_allclose(np.asarray(r0), np.asarray(x), atol=1e-6)


def test_rmsnorm_unit_scale():
    x = jnp.asarray([[3.0, -4.0]])
    out = M.rmsnorm(x, jnp.ones(2))
    ms = np.mean(np.asarray(x) ** 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) / np.sqrt(ms + 1e-5),
                               rtol=1e-6)
