# TRAIL reproduction — build/test entry points.
#
# Everything under `build` and `test` is hermetic: no network, no GPU,
# no Python. The Rust stack falls back to the embedded configuration
# (`Config::embedded_default`) and deterministic synthetic probe weights
# when the `artifacts/` directory is absent.

.PHONY: build test bench-sim bench-dispatch fmt artifacts clean

build:
	cargo build --release

test:
	cargo test -q

# Queueing-theory benches run without PJRT or artifacts.
bench-sim:
	cargo bench -p trail --bench fig8_queue_sim
	cargo bench -p trail --bench lemma1_validation

# Multi-replica dispatch smoke: HTTP front-end over a 2-replica mock
# pool (examples/replica_pool.rs). Hermetic and fast (~seconds).
bench-dispatch:
	cargo run --release --example replica_pool -- --n 24 --rate 200 --replicas 2 --dispatch jsq

fmt:
	cargo fmt

# The Python AOT pipeline (python/compile/aot.py) writes
# artifacts/config.json, the HLO-text executables, trained probe
# weights, and golden traces. It needs JAX and is NOT required for
# `make build` / `make test`: without artifacts the crate uses
# Config::embedded_default() (a verbatim mirror of
# python/compile/config.py) and ProbeWeights::synthetic(), and the
# PJRT-only tests/benches are feature-gated behind `--features pjrt`.
artifacts:
	@echo "artifacts/ is produced by the Python AOT pipeline:"
	@echo "    cd python && python -m compile.aot --outdir ../artifacts"
	@echo "It requires JAX; the Rust build and tests do NOT need it —"
	@echo "they fall back to the embedded config and synthetic probe"
	@echo "weights (see rust/src/config.rs and runtime/probe_weights.rs)."

clean:
	cargo clean
	rm -rf python/__pycache__ python/compile/__pycache__ python/tests/__pycache__
