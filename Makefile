# TRAIL reproduction — build/test entry points.
#
# Everything under `build` and `test` is hermetic: no network, no GPU,
# no Python. The Rust stack falls back to the embedded configuration
# (`Config::embedded_default`) and deterministic synthetic probe weights
# when the `artifacts/` directory is absent.

.PHONY: build test bench-sim bench-dispatch bench-sim-json bench-sim-diff bench-sim-refresh \
        bench-sched bench-sched-diff bench-sched-refresh \
        bench-fair bench-fair-diff bench-fair-refresh \
        bench-prefix bench-prefix-diff bench-prefix-refresh \
        bench-pred bench-pred-diff bench-pred-refresh \
        bench-obs bench-obs-diff bench-obs-refresh \
        bench-scale bench-scale-diff bench-scale-refresh bench-scale-mirror \
        bench-fleet bench-fleet-diff bench-fleet-refresh bench-fleet-mirror \
        bench-freeze bench-freeze-mirror \
        fmt artifacts clean

build:
	cargo build --release

test:
	cargo test -q

# Queueing-theory benches run without PJRT or artifacts.
bench-sim:
	cargo bench -p trail --bench fig8_queue_sim
	cargo bench -p trail --bench lemma1_validation

# Multi-replica dispatch smoke: HTTP front-end over a 2-replica mock
# pool (examples/replica_pool.rs). Hermetic and fast (~seconds).
bench-dispatch:
	cargo run --release --example replica_pool -- --n 24 --rate 200 --replicas 2 --dispatch jsq

# simlab: deterministic virtual-time co-simulation sweep (FCFS vs SRPT
# vs TRAIL x {steady, bursty, multi-tenant, skewed} x {2, 4} replicas,
# migration on). Runs the full grid twice and `cmp`s the two
# BENCH_*.json files byte-for-byte — the hard determinism gate.
# Hermetic: embedded config, mock backend, virtual clocks, no threads.
bench-sim-json:
	cargo run --release --bin trail-serve -- sim --out BENCH_sim.json
	cargo run --release --bin trail-serve -- sim --out BENCH_sim.run2.json
	cmp BENCH_sim.json BENCH_sim.run2.json
	rm -f BENCH_sim.run2.json

# Diff the sweep against the checked-in baseline. A diff means a real
# behaviour change: intentional -> `make bench-sim-refresh` and commit
# the new baseline in the same PR; otherwise it is a regression.
bench-sim-diff: bench-sim-json
	diff -u benchmarks/BENCH_seed.json BENCH_sim.json

# Refresh the checked-in simlab baseline after an *intentional*
# scheduler / cost-model / scenario change. Commit the resulting diff
# in the same PR that caused it (see docs/simlab.md).
bench-sim-refresh:
	cargo run --release --bin trail-serve -- sim --out benchmarks/BENCH_seed.json

# Scheduler-scale selector comparison (docs/scheduler.md): reference
# full-sort vs incremental rank index over the scale-1k / scale-10k /
# scale-replicas grid. Run twice and `cmp` byte-for-byte — the hard
# determinism gate for the selector work counters.
bench-sched:
	cargo run --release --bin trail-serve -- sched --out BENCH_sched.json
	cargo run --release --bin trail-serve -- sched --out BENCH_sched.run2.json
	cmp BENCH_sched.json BENCH_sched.run2.json
	rm -f BENCH_sched.run2.json

# Diff against the checked-in scaling baseline (advisory in CI, same
# libm caveat as bench-sim-diff).
bench-sched-diff: bench-sched
	diff -u benchmarks/BENCH_sched.json BENCH_sched.json

bench-sched-refresh:
	cargo run --release --bin trail-serve -- sched --out benchmarks/BENCH_sched.json

# Fairness grid (docs/fairness.md): starvation guard + per-tenant
# shares over the fair-* scenarios, plus the 128-replica dispatch x
# fairness sweep. Run twice and `cmp` byte-for-byte — the hard
# determinism gate for the fairness layer.
bench-fair:
	cargo run --release --bin trail-serve -- fair --out BENCH_fair.json
	cargo run --release --bin trail-serve -- fair --out BENCH_fair.run2.json
	cmp BENCH_fair.json BENCH_fair.run2.json
	rm -f BENCH_fair.run2.json

# Diff against the checked-in fairness baseline (advisory in CI, same
# libm caveat as bench-sim-diff).
bench-fair-diff: bench-fair
	diff -u benchmarks/BENCH_fair.json BENCH_fair.json

bench-fair-refresh:
	cargo run --release --bin trail-serve -- fair --out benchmarks/BENCH_fair.json

# Prefix-cache grid (docs/prefix_cache.md): agentic/RAG prefix-sharing
# workloads x sharing factor x {least-work, affinity} dispatch. Run
# twice and `cmp` byte-for-byte — the hard determinism gate for the
# radix trie, refcounted charging, and cache-affinity dispatch.
bench-prefix:
	cargo run --release --bin trail-serve -- prefix --out BENCH_prefix.json
	cargo run --release --bin trail-serve -- prefix --out BENCH_prefix.run2.json
	cmp BENCH_prefix.json BENCH_prefix.run2.json
	rm -f BENCH_prefix.run2.json

# Diff against the checked-in prefix baseline (advisory in CI, same
# libm caveat as bench-sim-diff).
bench-prefix-diff: bench-prefix
	diff -u benchmarks/BENCH_prefix.json BENCH_prefix.json

bench-prefix-refresh:
	cargo run --release --bin trail-serve -- prefix --out benchmarks/BENCH_prefix.json

# Predictor-arena grid (docs/predictors.md): probe/bucket/rank/online x
# fcfs/trail over the pred-steady + pred-drift scenarios, with
# Kendall-tau / inversion-rate / MAE quality columns. Run twice and
# `cmp` byte-for-byte — the hard determinism gate for the predictor
# subsystem (incl. the online-refresh EMA and the drift side-stream).
bench-pred:
	cargo run --release --bin trail-serve -- pred --out BENCH_pred.json
	cargo run --release --bin trail-serve -- pred --out BENCH_pred.run2.json
	cmp BENCH_pred.json BENCH_pred.run2.json
	rm -f BENCH_pred.run2.json

# Diff against the checked-in predictor baseline (advisory in CI, same
# libm caveat as bench-sim-diff).
bench-pred-diff: bench-pred
	diff -u benchmarks/BENCH_pred.json BENCH_pred.json

bench-pred-refresh:
	cargo run --release --bin trail-serve -- pred --out benchmarks/BENCH_pred.json

# Flight-recorder grid (docs/observability.md): scale-1k x
# {fcfs, trail-c0.8} at 2 replicas with tracing + phase timing on. Run
# twice and `cmp` both the report and the rendered trace byte-for-byte
# — the hard determinism gate for the recorder itself (event order,
# line format, FNV fingerprint).
bench-obs:
	cargo run --release --bin trail-serve -- obs --out BENCH_obs.json --trace-jsonl trace_obs.jsonl --timings-json timings_obs.json
	cargo run --release --bin trail-serve -- obs --out BENCH_obs.run2.json --trace-jsonl trace_obs.run2.jsonl
	cmp BENCH_obs.json BENCH_obs.run2.json
	cmp trace_obs.jsonl trace_obs.run2.jsonl
	rm -f BENCH_obs.run2.json trace_obs.run2.jsonl

# Diff against the checked-in flight-recorder baseline (advisory in CI,
# same libm caveat as bench-sim-diff).
bench-obs-diff: bench-obs
	diff -u benchmarks/BENCH_obs.json BENCH_obs.json

bench-obs-refresh:
	cargo run --release --bin trail-serve -- obs --out benchmarks/BENCH_obs.json

# Parallel-driver scale grid (docs/simlab.md): scale-10k (epoch mode,
# JSQ dispatch) + scale-100k (sharded mode, round-robin) x the
# {1, 2, 4, 8}-worker ladder at 8 replicas. The report rows are
# worker-invariant by construction (byte-identity is the whole point of
# the parallel driver); wall-clock speedups land in timings_scale.json,
# never in the frozen report. Run twice and `cmp` byte-for-byte.
bench-scale:
	cargo run --release --bin trail-serve -- scale --out BENCH_scale.json --timings-json timings_scale.json
	cargo run --release --bin trail-serve -- scale --out BENCH_scale.run2.json
	cmp BENCH_scale.json BENCH_scale.run2.json
	rm -f BENCH_scale.run2.json

# Diff against the checked-in scale baseline (advisory in CI, same
# libm caveat as bench-sim-diff).
bench-scale-diff: bench-scale
	diff -u benchmarks/BENCH_scale.json BENCH_scale.json

bench-scale-refresh:
	cargo run --release --bin trail-serve -- scale --out benchmarks/BENCH_scale.json

# Same grid through the Python mirror (one serial run per scenario —
# the mirror has no parallel driver, which is exactly why the rows
# must be worker-invariant).
bench-scale-mirror:
	cd python && python3 simref.py scale --out /tmp/MIRROR_scale.json > /dev/null
	cmp /tmp/MIRROR_scale.json benchmarks/BENCH_scale.json
	rm -f /tmp/MIRROR_scale.json

# Fleet chaos grid (docs/fleet.md): {steady, diurnal, flash-crowd} x
# failure rate {0, 0.4} x autoscaler {off, on} on a 6-replica
# heterogeneous fleet — crash/recovery with redispatch, graceful-drain
# scale-down, stale dispatch snapshots, SLO-class admission control.
# Run twice and `cmp` byte-for-byte — the hard determinism gate for the
# whole fleet-dynamics event stream.
bench-fleet:
	cargo run --release --bin trail-serve -- fleet --out BENCH_fleet.json
	cargo run --release --bin trail-serve -- fleet --out BENCH_fleet.run2.json
	cmp BENCH_fleet.json BENCH_fleet.run2.json
	rm -f BENCH_fleet.run2.json

# Diff against the checked-in chaos-grid baseline (advisory in CI, same
# libm caveat as bench-sim-diff).
bench-fleet-diff: bench-fleet
	diff -u benchmarks/BENCH_fleet.json BENCH_fleet.json

bench-fleet-refresh:
	cargo run --release --bin trail-serve -- fleet --out benchmarks/BENCH_fleet.json

# Same grid through the Python mirror — the in-image verification
# substrate when cargo is unavailable (this is also how the checked-in
# baseline was generated; see docs/fleet.md).
bench-fleet-mirror:
	cd python && python3 simref.py fleet --out /tmp/MIRROR_fleet.json > /dev/null
	cmp /tmp/MIRROR_fleet.json benchmarks/BENCH_fleet.json
	rm -f /tmp/MIRROR_fleet.json

# Baseline freeze (docs/observability.md): regenerate every checked-in
# BENCH baseline with the recorder *disabled* and fail on any byte
# drift. This is the zero-cost-when-disabled gate — landing the
# observability layer must not move a single frozen byte.
bench-freeze:
	cargo run --release --bin trail-serve -- sim --out /tmp/FREEZE_seed.json
	cmp /tmp/FREEZE_seed.json benchmarks/BENCH_seed.json
	cargo run --release --bin trail-serve -- sched --out /tmp/FREEZE_sched.json
	cmp /tmp/FREEZE_sched.json benchmarks/BENCH_sched.json
	cargo run --release --bin trail-serve -- fair --out /tmp/FREEZE_fair.json
	cmp /tmp/FREEZE_fair.json benchmarks/BENCH_fair.json
	cargo run --release --bin trail-serve -- prefix --out /tmp/FREEZE_prefix.json
	cmp /tmp/FREEZE_prefix.json benchmarks/BENCH_prefix.json
	cargo run --release --bin trail-serve -- pred --out /tmp/FREEZE_pred.json
	cmp /tmp/FREEZE_pred.json benchmarks/BENCH_pred.json
	cargo run --release --bin trail-serve -- scale --out /tmp/FREEZE_scale.json
	cmp /tmp/FREEZE_scale.json benchmarks/BENCH_scale.json
	cargo run --release --bin trail-serve -- fleet --out /tmp/FREEZE_fleet.json
	cmp /tmp/FREEZE_fleet.json benchmarks/BENCH_fleet.json
	rm -f /tmp/FREEZE_*.json

# Same freeze gate through the dependency-free Python mirror — the
# in-image verification substrate when cargo is unavailable.
bench-freeze-mirror:
	cd python && python3 simref.py sweep --out /tmp/FREEZE_seed.json > /dev/null
	cmp /tmp/FREEZE_seed.json benchmarks/BENCH_seed.json
	cd python && python3 simref.py sched --out /tmp/FREEZE_sched.json > /dev/null
	cmp /tmp/FREEZE_sched.json benchmarks/BENCH_sched.json
	cd python && python3 simref.py fair --out /tmp/FREEZE_fair.json > /dev/null
	cmp /tmp/FREEZE_fair.json benchmarks/BENCH_fair.json
	cd python && python3 simref.py prefix --out /tmp/FREEZE_prefix.json > /dev/null
	cmp /tmp/FREEZE_prefix.json benchmarks/BENCH_prefix.json
	cd python && python3 simref.py pred --out /tmp/FREEZE_pred.json > /dev/null
	cmp /tmp/FREEZE_pred.json benchmarks/BENCH_pred.json
	cd python && python3 simref.py obs --out /tmp/FREEZE_obs.json > /dev/null
	cmp /tmp/FREEZE_obs.json benchmarks/BENCH_obs.json
	cd python && python3 simref.py scale --out /tmp/FREEZE_scale.json > /dev/null
	cmp /tmp/FREEZE_scale.json benchmarks/BENCH_scale.json
	cd python && python3 simref.py fleet --out /tmp/FREEZE_fleet.json > /dev/null
	cmp /tmp/FREEZE_fleet.json benchmarks/BENCH_fleet.json
	rm -f /tmp/FREEZE_*.json

fmt:
	cargo fmt

# The Python AOT pipeline (python/compile/aot.py) writes
# artifacts/config.json, the HLO-text executables, trained probe
# weights, and golden traces. It needs JAX and is NOT required for
# `make build` / `make test`: without artifacts the crate uses
# Config::embedded_default() (a verbatim mirror of
# python/compile/config.py) and ProbeWeights::synthetic(), and the
# PJRT-only tests/benches are feature-gated behind `--features pjrt`.
artifacts:
	@echo "artifacts/ is produced by the Python AOT pipeline:"
	@echo "    cd python && python -m compile.aot --outdir ../artifacts"
	@echo "It requires JAX; the Rust build and tests do NOT need it —"
	@echo "they fall back to the embedded config and synthetic probe"
	@echo "weights (see rust/src/config.rs and runtime/probe_weights.rs)."

clean:
	cargo clean
	rm -rf python/__pycache__ python/compile/__pycache__ python/tests/__pycache__
